"""Public batched-simplex-projection op with custom (implicit) JVP.

The bisection kernel is exact but autodiff-opaque (fori_loop over selects);
we attach the closed-form Jacobian from the paper (App. C):

    ∂proj(y) = diag(s) − s sᵀ / |s|₁,   s = 1[proj(y) > 0]

via jax.custom_jvp — the same implicit-differentiation move the paper makes,
applied at the kernel boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.simplex_proj.kernel import projection_simplex_rows


@functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2))
def projection_simplex_batched(y, scale: float = 1.0,
                               interpret: bool = False):
    """y: (..., d) → row-wise simplex projection (Pallas bisection kernel)."""
    shape = y.shape
    flat = y.reshape(-1, shape[-1])
    R = flat.shape[0]
    rows_block = 8 if R % 8 == 0 else (4 if R % 4 == 0 else 1)
    out = projection_simplex_rows(flat, scale=scale, rows_block=rows_block,
                                  interpret=interpret)
    return out.reshape(shape)


@projection_simplex_batched.defjvp
def _jvp(scale, interpret, primals, tangents):
    (y,), (dy,) = primals, tangents
    x = projection_simplex_batched(y, scale, interpret)
    s = (x > 0).astype(dy.dtype)
    inner = jnp.sum(s * dy, axis=-1, keepdims=True) / \
        jnp.maximum(jnp.sum(s, axis=-1, keepdims=True), 1.0)
    return x, s * (dy - inner)
