"""Oracle: the sort-based simplex projection from the core library."""
from repro.core.projections import projection_simplex as projection_simplex_ref  # noqa: F401
