"""Deterministic ``(seed, step)``-keyed minibatch sampling.

The stochastic solver layer declares its optimality mapping *in
expectation* over a data distribution; everything downstream (restart
safety, bit-identical replays, variance-reduced backward operators)
hinges on minibatch selection being a pure function of ``(seed, step)``.
:class:`MinibatchSampler` therefore computes indices **host-side** with
NumPy (so they are trace-time constants — jit/vmap never see data
movement logic) and gathers rows **on device** with ``jnp.take``.

Two independent index streams are derived from the same seed:

* the *forward* stream ``(seed, 0, step)`` drives the training
  minibatches consumed by :func:`repro.stochastic.run_stochastic`;
* the *backward* stream ``(seed, 1, j)`` draws the ``k`` resampled
  minibatches that :class:`repro.core.SampledJacobianOperator` averages
  Hessian-vector products over.

Keeping the streams disjoint means the backward operator's variance is
independent of where the forward loop stopped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leading_dim(data: Any) -> int:
    """The (common) leading-axis length of every leaf in ``data``."""
    leaves = jax.tree_util.tree_leaves(data)
    if not leaves:
        raise ValueError("MinibatchSampler needs a non-empty data pytree.")
    sizes = {int(np.shape(leaf)[0]) for leaf in leaves}
    if len(sizes) != 1:
        raise ValueError(
            f"data leaves disagree on leading axis length: {sorted(sizes)}")
    return sizes.pop()


@dataclasses.dataclass(frozen=True)
class MinibatchSampler:
    """Deterministic, restart-safe minibatch sampler over an in-memory pytree.

    ``data`` is any pytree whose leaves share a leading example axis of
    length ``n``; a minibatch is the same pytree with the leading axis
    gathered down to ``batch_size``.  Sampling is *without replacement
    within a batch* and keyed purely by ``(seed, step)``: the same seed
    replays the identical index trajectory, and a run restarted at step
    ``k`` continues exactly where the original left off.
    """

    data: Any
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        """Validate the batch size against the dataset length."""
        n = self.num_examples
        if not 0 < self.batch_size <= n:
            raise ValueError(
                f"batch_size={self.batch_size} must be in [1, n={n}]")

    @property
    def num_examples(self) -> int:
        """Dataset length ``n`` (leading-axis length of every leaf)."""
        return _leading_dim(self.data)

    @property
    def num_batches(self) -> int:
        """Minibatches per epoch, ``n // batch_size``."""
        return self.num_examples // self.batch_size

    def _rng(self, *key: int) -> np.random.Generator:
        """A NumPy generator keyed by ``(seed, *key)`` (pure, host-side)."""
        return np.random.default_rng(
            np.random.SeedSequence((self.seed,) + key))

    def indices(self, step: int) -> np.ndarray:
        """Forward-stream indices for ``step``: shape ``(batch_size,)``."""
        return self._rng(0, int(step)).choice(
            self.num_examples, size=self.batch_size, replace=False)

    def batch_indices(self, start_step: int, num_steps: int) -> np.ndarray:
        """Stacked forward indices for steps ``[start, start + num)``.

        Shape ``(num_steps, batch_size)`` — the whole index plan of a
        ``lax.scan`` inner loop, computed host-side at trace time.
        """
        return np.stack(
            [self.indices(s) for s in range(start_step,
                                            start_step + num_steps)])

    def gather(self, idx) -> Any:
        """Device-side gather of rows ``idx`` from every data leaf."""
        idx = jnp.asarray(idx)
        return jax.tree_util.tree_map(
            lambda leaf: jnp.take(jnp.asarray(leaf), idx, axis=0), self.data)

    def batch_at(self, step: int) -> Any:
        """The minibatch for ``step`` — pure in ``(seed, step)``."""
        return self.gather(self.indices(step))

    def backward_batches(self, k: int) -> Any:
        """``k`` resampled minibatches stacked on a new leading axis.

        Drawn from the backward stream ``(seed, 1, j)`` so they are
        decorrelated from the forward trajectory; feed the result to
        :class:`repro.core.SampledJacobianOperator`, whose matvec
        averages Hessian-vector products over this axis.
        """
        idx = np.stack([self._rng(1, j).choice(
            self.num_examples, size=self.batch_size, replace=False)
            for j in range(k)])
        return self.gather(idx)

    @classmethod
    def from_stream(cls, stream, num_steps: int, *,
                    batch_size: Optional[int] = None,
                    seed: Optional[int] = None,
                    start_step: int = 0) -> "MinibatchSampler":
        """Materialize a sampler from a ``batch_at(step)`` data stream.

        Concatenates ``num_steps`` consecutive stream batches (e.g. from
        :class:`repro.data.SyntheticLMStream` or a seekable
        :class:`repro.data.PrefetchIterator`) along the example axis into
        one in-memory dataset of ``num_steps * stream_batch`` examples.
        ``batch_size`` defaults to the stream's own batch size and
        ``seed`` to the stream config's seed when available.
        """
        batches = [stream.batch_at(start_step + s) for s in range(num_steps)]
        data = jax.tree_util.tree_map(
            lambda *leaves: jnp.concatenate(leaves, axis=0), *batches)
        if batch_size is None:
            batch_size = _leading_dim(batches[0])
        if seed is None:
            # SyntheticLMStream carries its DataConfig as .cfg; a
            # PrefetchIterator exposes the stream one level down.
            cfg = getattr(stream, "cfg", None) or getattr(
                getattr(stream, "stream", None), "cfg", None)
            seed = int(getattr(cfg, "seed", 0))
        return cls(data=data, batch_size=batch_size, seed=seed)
