"""Stochastic inner solvers: minibatch bilevel optimization at data scale.

The subsystem that lets implicit differentiation ride on *stochastic*
inner solvers (the paper only needs an approximate root of the optimality
mapping — Blondel et al. 2022, §3.3):

  * :class:`MinibatchSampler` — deterministic ``(seed, step)``-keyed
    sampling: indices host-side (trace-time constants), gathers on
    device; restart-safe and jit/vmap-safe.
  * :class:`StochasticSolver` — the protocol on the ``IterativeSolver``
    seam, optimality declared in expectation; :class:`SGD`,
    :class:`MomentumSGD`, :class:`Adam` instances;
    :func:`run_stochastic` the shared scan driver with Polyak/EMA
    averaging and a full-batch residual diagnostic.
  * implicit diff at the averaged iterate through a sampled Jacobian
    operator (``repro.core.SampledJacobianOperator``) and the PR-7
    approximate backward modes.
  * :func:`make_stochastic_train_step` / :func:`stochastic_data_iter` —
    host-side adapters onto ``repro.runtime.train_loop``.

See ``docs/stochastic.md`` for the contracts and a data-scale
reweighting walkthrough.
"""
from repro.stochastic.sampler import MinibatchSampler
from repro.stochastic.solvers import (AVERAGING_MODES, BACKWARD_DATA_MODES,
                                      Adam, MomentumSGD, SGD,
                                      StochasticSolver, run_stochastic)
from repro.stochastic.host import (make_stochastic_train_step,
                                   stochastic_data_iter)

__all__ = [
    "MinibatchSampler",
    "StochasticSolver", "SGD", "MomentumSGD", "Adam", "run_stochastic",
    "AVERAGING_MODES", "BACKWARD_DATA_MODES",
    "make_stochastic_train_step", "stochastic_data_iter",
]
