"""Drive a ``StochasticSolver`` through the production training loop.

:func:`repro.stochastic.run_stochastic` is the jit/vmap-safe ``lax.scan``
driver — the one implicit differentiation wraps.  This module is the
*host-side* alternative for data-scale runs that want the production
machinery instead: checkpoints, straggler monitoring, preemption handling
— everything ``repro.runtime.train_loop.train_loop`` already provides.

The adapters are thin by design: :func:`make_stochastic_train_step` turns
``solver.update`` into the ``(state, x, y) -> (state, metrics)`` contract
of ``train_loop``, and :func:`stochastic_data_iter` turns the solver's
:class:`~repro.stochastic.sampler.MinibatchSampler` into the
``(step, (x, y))`` iterator it consumes.  Because the sampler is
``(seed, step)``-keyed, a loop restarted at ``start_step=k`` (e.g. after
a preemption) sees the identical minibatch sequence the original run
would have — checkpoint/restart composes with stochastic inner solves
for free.
"""
from __future__ import annotations

from typing import Callable

import jax


def stochastic_data_iter(sampler, start_step: int = 0):
    """Yield ``(step, batch)`` pairs ``train_loop``-style from a sampler.

    ``sampler.data`` must be an ``(inputs, labels)``-like 2-tuple so
    ``train_loop``'s ``data_step, (x, y) = next(data_iter)`` unpacking
    holds.  Restart-safe: pass the checkpointed step as ``start_step``.
    """
    step = start_step
    while True:
        yield step, sampler.batch_at(step)
        step += 1


def make_stochastic_train_step(solver, *theta, jit: bool = True) -> Callable:
    """Adapt ``solver.update`` to the ``train_loop`` step contract.

    The carried state is ``(params, solver_state)`` — initialize it with
    ``(init_params, solver.init_state(init_params, *theta))``.  Metrics
    report the post-step minibatch loss and the minibatch-gradient norm
    (the cheap proxy; measure ``solver.l2_optimality_error`` full-batch
    outside the loop for the honest residual).
    """
    def step(carry, x, y):
        params, state = carry
        batch = (x, y)
        new_params, new_state = solver.update(params, state, batch, *theta)
        metrics = {"loss": solver.fun(new_params, batch, *theta),
                   "grad_norm": new_state.error,
                   "step": new_state.iter_num}
        return (new_params, new_state), metrics

    return jax.jit(step) if jit else step
