"""Stochastic inner solvers on the ``IterativeSolver`` seam.

The deterministic runtime declares an optimality mapping ``F(x, θ)`` and
iterates it full-batch.  This module declares optimality **in
expectation** over a data distribution,

    F(x, θ) = E_b[ ∇₁ fun(x, b, θ) ] = 0,

with ``fun(params, batch, *theta)`` a minibatch objective whose uniform-
minibatch expectation equals the full-batch objective (use per-example
*means*, not sums — the contract every instance below relies on).

Pieces:

  * :class:`StochasticSolver` — the protocol: ``init_state(params,
    *theta)``, ``update(params, state, batch, *theta)`` (one minibatch
    step), and ``optimality_fun`` = the full-batch gradient.  Everything
    the deterministic ``IterativeSolver`` provides (``run()`` self-wrapping
    with implicit diff, ``diff_spec()``, registry-routed backward solves,
    the PR-7 approximate backward modes) is inherited.
  * :func:`run_stochastic` — the shared driver: a ``lax.scan`` over a
    host-precomputed ``(steps, B)`` index plan from the
    :class:`~repro.stochastic.sampler.MinibatchSampler` (restart-safe,
    jit/vmap-safe), with Polyak / EMA iterate averaging so the returned
    point — the one implicit diff linearizes at — is the *averaged* fixed
    point, and a final full-batch residual as the honest convergence
    diagnostic in ``OptInfo``.
  * :class:`SGD` / :class:`MomentumSGD` / :class:`Adam` — the instances.

Implicit differentiation at the averaged iterate defaults to a *sampled*
system: ``diff_spec()`` carries a ``system_operator`` factory building a
:class:`repro.core.SampledJacobianOperator` whose matvec averages
Hessian-vector products over ``backward_batches`` freshly resampled
minibatches (``backward_data="sampled"``; ``"full"`` restores the exact
full-batch operator).  The backward *treatment* defaults to the PR-7
``neumann_k`` truncation — running CG to 1e-12 on a noisy sampled
operator is false precision; spend a fixed matvec budget instead and
read the honesty check off ``estimate_hypergrad_error`` (measured
against the **full-batch** residual, so sampling error is visible too).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import diff_api
from repro.core import operators as ops
from repro.observability import events as obs_events
from repro.core.linear_solve import _tree_l2, _tree_sub
from repro.core.solver_runtime import (IterativeSolver, OptInfo, _inf_like,
                                       _kw, _tree_axpy)
from repro.stochastic.sampler import MinibatchSampler


AVERAGING_MODES = ("polyak", "ema", "last")
BACKWARD_DATA_MODES = ("sampled", "full")


# ---------------------------------------------------------------------------
# the shared driver
# ---------------------------------------------------------------------------

def _update_average(solver: "StochasticSolver", avg, params, iter_num):
    """One averaging step; ``iter_num`` counts completed updates (≥ 1)."""
    if solver.averaging == "last":
        return params
    if solver.averaging == "ema":
        d = solver.ema_decay
        return jax.tree_util.tree_map(
            lambda a, p: d * a + (1.0 - d) * p, avg, params)
    if solver.averaging == "polyak":
        # tail averaging: reset until ``average_from`` updates have burned
        # in, then the running mean over the remaining m = k - from steps
        m = jnp.maximum(iter_num - solver.average_from, 1)
        return jax.tree_util.tree_map(
            lambda a, p: jnp.where(iter_num <= solver.average_from,
                                   p, a + (p - a) / m), avg, params)
    raise ValueError(f"unknown averaging mode {solver.averaging!r}; "
                     f"expected one of {AVERAGING_MODES}")


def run_stochastic(solver: "StochasticSolver", init_params, *theta,
                   steps: Optional[int] = None, start_step: int = 0,
                   init_state=None, init_average=None):
    """Drive ``solver`` for a step budget; return ``(x̄, OptInfo)``.

    The minibatch index plan ``(steps, B)`` is computed host-side by the
    solver's sampler — a pure function of ``(seed, step)`` — and becomes a
    trace-time constant of one ``lax.scan``; batches are gathered on
    device inside the scan body.  Consequences:

      * **restart safety** — ``start_step=k`` with the step-``k``
        ``init_state``/``init_average`` replays the exact tail of a
        longer run, bit for bit;
      * **jit/vmap safety** — no host callbacks in the loop; ``jax.vmap``
        over θ batches the whole inner loop as one scan.

    The returned iterate is the Polyak/EMA average (per
    ``solver.averaging``) — the point implicit differentiation linearizes
    at — and ``OptInfo.error`` is the **full-batch** optimality residual
    at that point (the held-out diagnostic; per-step ``state.error`` is
    only the cheap minibatch-gradient proxy).
    """
    sampler = solver.sampler
    if sampler is None:
        raise ValueError(f"{type(solver).__name__} needs a MinibatchSampler "
                         "(sampler=...) to run")
    if steps is None:
        steps = solver.num_steps()
    idx = jnp.asarray(sampler.batch_indices(start_step, steps))
    state = solver.init_state(init_params, *theta) if init_state is None \
        else init_state
    avg = init_params if init_average is None else init_average

    def body(carry, idx_t):
        params, state, avg = carry
        batch = sampler.gather(idx_t)
        new_params, new_state = solver.update(params, state, batch, *theta)
        new_avg = _update_average(solver, avg, new_params,
                                  new_state.iter_num)
        return (new_params, new_state, new_avg), None

    (params, state, avg), _ = lax.scan(body, (init_params, state, avg), idx)
    x_star = avg
    error = solver.l2_optimality_error(x_star, *theta)
    info = OptInfo(iterations=state.iter_num, error=error,
                   converged=error <= solver.tol)
    # staged AFTER the scan (never inside it — the loop body stays free of
    # host callbacks, preserving the restart/vmap contract above); a
    # trace-time no-op unless observability is enabled
    obs_events.jit_event("converged",
                         {"solver": type(solver).__name__,
                          "averaging": str(solver.averaging)},
                         iterations=info.iterations, error=info.error,
                         converged=info.converged)
    return x_star, info


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class StochasticSolver(IterativeSolver):
    """Minibatch solver protocol: optimality declared in expectation.

    ``fun(params, batch, *theta)`` is the minibatch objective; it MUST be
    a per-example mean so that its expectation over uniform minibatches
    equals the full-batch objective — then the full-batch gradient
    ``optimality_fun`` is exactly the expectation residual the implicit
    function theorem is applied to.  ``sampler`` supplies deterministic
    ``(seed, step)``-keyed minibatches (and the dataset itself, for the
    full-batch residual/operator).

    Budget: ``steps`` (exact update count) or ``epochs`` (×
    ``sampler.num_batches``); defaults to one epoch.  ``averaging``
    selects the returned/differentiated iterate: ``"polyak"`` (running
    mean from update ``average_from``+1 on), ``"ema"`` (decay
    ``ema_decay``), or ``"last"``.

    Backward: ``backward_data="sampled"`` (default) builds the implicit
    system from a ``SampledJacobianOperator`` over ``backward_batches``
    freshly drawn minibatches; ``"full"`` uses the exact full-batch
    Jacobian.  The treatment defaults to ``backward="neumann_k"`` (PR 7)
    — exact CG on a noisy operator is false precision; switch back with
    ``backward="exact"``.  ``solve`` defaults to ``"cg"``: the per-batch
    residual is a gradient mapping, so the (sampled or full) system is
    symmetric.

    Subclasses implement ``init_state(params, *theta)`` and
    ``update(params, state, batch, *theta) -> (params, state)`` — note
    the extra ``batch`` argument relative to the deterministic protocol.
    """
    fun: Callable = None
    sampler: MinibatchSampler = None
    steps: Optional[int] = _kw(None)
    epochs: Optional[int] = _kw(None)
    averaging: str = _kw("polyak")
    ema_decay: float = _kw(0.99)
    average_from: int = _kw(0)
    backward_data: str = _kw("sampled")
    backward_batches: int = _kw(4)
    # stochastic defaults overriding the deterministic base: symmetric
    # solve routing (gradient-mapping Hessians) + truncated backward.
    # neumann_k NEEDS the Jacobi preconditioner here: the implicit system
    # is a stationarity declaration (A = −H), where unpreconditioned
    # Richardson diverges unconditionally — M⁻¹ = diag(A)⁻¹ flips the sign
    # back and contracts for reasonably-conditioned Hessians (the PR-7
    # pairing).  diag(A) costs d probing matvecs of the sampled operator,
    # derived once per backward; at large d prefer backward="exact" (CG on
    # the sampled operator) or a callable precond instead.
    solve: Union[str, Callable] = _kw("cg")
    backward: str = _kw("neumann_k")
    precond: Any = _kw("jacobi")

    # drivers (bilevel) detect stochastic solvers through this marker
    is_stochastic = True

    # -- protocol ----------------------------------------------------------
    def minibatch_grad(self, params, batch, *theta):
        """∇₁ fun at one minibatch — the stochastic residual sample."""
        return jax.grad(self.fun, argnums=0)(params, batch, *theta)

    def optimality_fun(self, params, *theta):
        """The expectation residual: the full-batch gradient over
        ``sampler.data`` (what implicit diff linearizes)."""
        return jax.grad(self.fun, argnums=0)(params, self.sampler.data,
                                             *theta)

    def update(self, params, state, batch, *theta):
        """One minibatch step: ``(params, state, batch) → (params, state)``."""
        raise NotImplementedError

    # -- budget ------------------------------------------------------------
    def num_steps(self) -> int:
        """Resolve the update budget (``steps`` wins; default one epoch)."""
        if self.steps is not None:
            return int(self.steps)
        if self.epochs is not None:
            return int(self.epochs) * self.sampler.num_batches
        return self.sampler.num_batches

    # -- driver ------------------------------------------------------------
    def _iterate(self, init_params, *theta):
        """The raw stochastic loop (no implicit diff attached)."""
        return run_stochastic(self, init_params, *theta)

    # -- implicit diff at the averaged iterate -----------------------------
    def _system_operator(self, x_star, theta_args, *, symmetric=None):
        """``ImplicitDiffSpec.system_operator`` factory: the sampled
        implicit system ``A = -∂₁F`` as a ``SampledJacobianOperator``
        averaging Hessian-vector products over ``backward_batches``
        minibatches from the sampler's backward stream.  Symmetry is
        certified structurally: each per-batch residual is a gradient
        mapping, so every sample (hence the mean) is a Hessian."""
        del symmetric  # structural certification is strictly stronger
        batches = self.sampler.backward_batches(self.backward_batches)

        def residual(x, batch):
            return jax.grad(self.fun, argnums=0)(x, batch, *theta_args)

        return ops.SampledJacobianOperator(residual, x_star, batches,
                                           negate=True, symmetric=True)

    def diff_spec(self) -> diff_api.ImplicitDiffSpec:
        """The inherited spec, plus the sampled system operator when
        ``backward_data="sampled"``."""
        if self.backward_data not in BACKWARD_DATA_MODES:
            raise ValueError(
                f"unknown backward_data {self.backward_data!r}; expected "
                f"one of {BACKWARD_DATA_MODES}")
        spec = super().diff_spec()
        if self.backward_data == "sampled":
            spec = spec.replace(system_operator=self._system_operator)
        return spec

    def estimate_hypergrad_error(self, params, *theta, cotangent=None):
        """Relative residual of the cotangent system — measured against
        the **full-batch** operator.

        Replays the configured backward treatment (sampled operator,
        approximate mode) to get ``u``, then spends one full-batch
        Hessian-vector product on ``‖v − Aᵀ_full u‖/‖v‖`` — so the
        estimate accounts for BOTH the truncation error of the
        approximate backward AND the minibatch sampling error of the
        operator, unlike the base class which measures against the same
        (possibly sampled) operator it solved with.
        """
        if cotangent is None:
            cotangent = jax.tree_util.tree_map(jnp.ones_like, params)
        spec = self.diff_spec()
        A = diff_api._implicit_system_operator(
            spec.residual_fun, params, theta, spec.solve,
            system_operator=spec.system_operator)
        precond = spec.precond
        if isinstance(precond, str):
            damped = ops.RidgeShifted(A, spec.ridge) if spec.ridge else A
            make = (ops.jacobi_preconditioner_from if precond == "jacobi"
                    else ops.block_jacobi_preconditioner)
            precond = make(damped)
        u = diff_api._backward_apply(
            A.T, cotangent, solve=spec.solve, tol=spec.tol,
            maxiter=spec.maxiter, ridge=spec.ridge, precond=precond,
            backward=spec.backward, backward_iters=spec.backward_iters,
            batch_ndim=0, error_estimate=False, return_info=False)
        A_full = ops.JacobianOperator(
            lambda x: self.optimality_fun(x, *theta), params, negate=True,
            symmetric=True)
        residual = _tree_sub(cotangent, A_full.rmatvec(u))
        return _tree_l2(residual) / jnp.maximum(_tree_l2(cotangent), 1e-30)


# ---------------------------------------------------------------------------
# instances
# ---------------------------------------------------------------------------

def _resolve_stepsize(stepsize, iter_num):
    """A constant or an ``fn(step) -> η`` schedule (step = 0-based)."""
    return stepsize(iter_num) if callable(stepsize) else stepsize


class SGDState(NamedTuple):
    """Iteration state of ``SGD``; ``error`` is the minibatch-gradient
    norm (cheap proxy — the driver reports the full-batch residual)."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class SGD(StochasticSolver):
    """Plain SGD: ``x ← x − η(k) · ∇fun(x, batch, θ)``.

    ``stepsize`` is a constant or a schedule ``fn(step) -> η`` (e.g.
    ``lambda k: eta0 / (1 + gamma * k)`` — with Polyak averaging the
    classic variance-killing combination on strongly-convex problems).
    """
    stepsize: Union[float, Callable] = 1e-2

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        return SGDState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, batch, *theta):
        """See ``StochasticSolver.update``."""
        g = self.minibatch_grad(params, batch, *theta)
        eta = _resolve_stepsize(self.stepsize, state.iter_num)
        new_params = _tree_axpy(params, g, -eta)
        return new_params, SGDState(state.iter_num + 1, _tree_l2(g))


class MomentumSGDState(NamedTuple):
    """Iteration state of ``MomentumSGD`` (Polyak heavy-ball velocity)."""
    iter_num: jnp.ndarray
    error: jnp.ndarray
    velocity: Any


@dataclasses.dataclass(eq=False)
class MomentumSGD(StochasticSolver):
    """Heavy-ball SGD: ``v ← μv + g``; ``x ← x − η(k) · v``."""
    stepsize: Union[float, Callable] = 1e-2
    momentum: float = 0.9

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return MomentumSGDState(jnp.asarray(0), _inf_like(params), zeros)

    def update(self, params, state, batch, *theta):
        """See ``StochasticSolver.update``."""
        g = self.minibatch_grad(params, batch, *theta)
        v = _tree_axpy(g, state.velocity, self.momentum)
        eta = _resolve_stepsize(self.stepsize, state.iter_num)
        new_params = _tree_axpy(params, v, -eta)
        return new_params, MomentumSGDState(state.iter_num + 1,
                                            _tree_l2(g), v)


class AdamState(NamedTuple):
    """Iteration state of ``Adam`` (first/second moment trees)."""
    iter_num: jnp.ndarray
    error: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(eq=False)
class Adam(StochasticSolver):
    """Adam with bias correction (Kingma & Ba) on the minibatch gradient.

    Note Adam's fixed points are exactly the stationary points of the
    expected objective, so the expectation-form optimality contract — and
    implicit differentiation at the averaged iterate — is unchanged; only
    the path there differs from SGD.
    """
    stepsize: Union[float, Callable] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return AdamState(jnp.asarray(0), _inf_like(params), zeros, zeros)

    def update(self, params, state, batch, *theta):
        """See ``StochasticSolver.update``."""
        g = self.minibatch_grad(params, batch, *theta)
        t = state.iter_num + 1
        m = jax.tree_util.tree_map(
            lambda mi, gi: self.b1 * mi + (1.0 - self.b1) * gi, state.m, g)
        v = jax.tree_util.tree_map(
            lambda vi, gi: self.b2 * vi + (1.0 - self.b2) * gi * gi,
            state.v, g)
        tf = t.astype(jnp.result_type(float))
        c1 = 1.0 - self.b1 ** tf
        c2 = 1.0 - self.b2 ** tf
        eta = _resolve_stepsize(self.stepsize, state.iter_num)
        new_params = jax.tree_util.tree_map(
            lambda p, mi, vi: p - eta * (mi / c1) /
            (jnp.sqrt(vi / c2) + self.eps), params, m, v)
        return new_params, AdamState(t, _tree_l2(g), m, v)
