"""Deterministic data pipeline.

Offline container ⇒ synthetic token streams, but built like production:
  * deterministic per-(host, step) sharding — every host materializes only
    its slice of the global batch (what multi-host input pipelines do);
  * restart-safe: the stream is a pure function of (seed, step), so resuming
    from step k after a failure replays the exact same data;
  * double-buffered prefetch thread to overlap host→device transfer.

The synthetic LM distribution is a Zipfian-unigram + Markov-ish mixture so
losses move meaningfully during the example training runs (unlike uniform
noise, whose CE is flat at log V).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2


class SyntheticLMStream:
    """Deterministic, shardable synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # fixed Zipf unigram table + deterministic bigram shift pattern
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self.unigram = probs / probs.sum()
        self.shift = rng.integers(1, cfg.vocab_size, size=64)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(inputs, labels) for this host at ``step`` — pure function."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * c.num_hosts + c.host_id)
        base = rng.choice(c.vocab_size, p=self.unigram,
                          size=(self.local_batch, c.seq_len + 1))
        # inject learnable structure: token t+1 correlates with token t
        mask = rng.random((self.local_batch, c.seq_len + 1)) < 0.5
        shifted = (base + self.shift[step % 64]) % c.vocab_size
        seq = np.where(mask, shifted, base).astype(np.int32)
        return seq[:, :-1], seq[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread double buffering with seekable random access.

    Sequential use is unchanged: ``next(it)`` yields ``(step, batch)`` in
    order from ``start_step``.  On top of that:

      * ``batch_at(step)`` — a *seekable* accessor: consecutive steps are
        served straight from the prefetch buffer; any other step seeks
        (discarding stale buffered batches via a generation counter) and
        resumes prefetching from there.  This is what lets consumers that
        address data by step — ``repro.stochastic.MinibatchSampler`` and
        restart-after-preemption training loops — sit on a prefetched
        stream without giving up determinism.
      * clean shutdown — ``close()`` is idempotent, signals the worker and
        *joins* the thread; the context-manager form scopes it.  ``daemon``
        stays True by default (an unclosed iterator never blocks
        interpreter exit) but can be disabled where dangling daemon
        threads are unwanted (e.g. under test runners that assert on
        thread leaks).
    """

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 depth: int = 2, daemon: bool = True):
        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._lock = threading.Lock()
        self._gen = 0               # bumped by seek(); stale batches dropped
        self._produce_step = start_step
        self._next_step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=daemon)
        self.thread.start()

    def _worker(self):
        while not self._stop.is_set():
            with self._lock:
                gen, step = self._gen, self._produce_step
                self._produce_step = step + 1
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                with self._lock:
                    if gen != self._gen:    # a seek invalidated this batch
                        break
                try:
                    self.q.put((gen, step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                gen, step, batch = self.q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration from None
                continue
            if gen != self._gen:            # drop batches from before a seek
                continue
            self._next_step = step + 1
            return step, batch

    def seek(self, step: int):
        """Restart prefetching at ``step``; buffered batches are discarded.

        The generation counter makes this race-free against the worker: a
        batch produced under an old generation is dropped at the queue (by
        the worker) or at the consumer (by ``__next__``), never served.
        """
        with self._lock:
            self._gen += 1
            self._produce_step = step
            self._next_step = step
        while True:                          # drain stale buffered batches
            try:
                self.q.get_nowait()
            except queue.Empty:
                return

    def batch_at(self, step: int):
        """The batch for ``step`` — buffered when sequential, seek otherwise.

        Equivalent to ``stream.batch_at(step)`` (the stream is a pure
        function of ``(seed, step)``) but served from the prefetch buffer
        whenever ``step`` continues the current run.
        """
        if step != self._next_step:
            self.seek(step)
        got, batch = next(self)
        assert got == step, (got, step)
        return batch

    def close(self):
        """Stop the worker and join it (idempotent)."""
        self._stop.set()
        if self.thread.is_alive():
            self.thread.join(timeout=2)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
