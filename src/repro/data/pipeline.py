"""Deterministic data pipeline.

Offline container ⇒ synthetic token streams, but built like production:
  * deterministic per-(host, step) sharding — every host materializes only
    its slice of the global batch (what multi-host input pipelines do);
  * restart-safe: the stream is a pure function of (seed, step), so resuming
    from step k after a failure replays the exact same data;
  * double-buffered prefetch thread to overlap host→device transfer.

The synthetic LM distribution is a Zipfian-unigram + Markov-ish mixture so
losses move meaningfully during the example training runs (unlike uniform
noise, whose CE is flat at log V).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2


class SyntheticLMStream:
    """Deterministic, shardable synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # fixed Zipf unigram table + deterministic bigram shift pattern
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self.unigram = probs / probs.sum()
        self.shift = rng.integers(1, cfg.vocab_size, size=64)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(inputs, labels) for this host at ``step`` — pure function."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * c.num_hosts + c.host_id)
        base = rng.choice(c.vocab_size, p=self.unigram,
                          size=(self.local_batch, c.seq_len + 1))
        # inject learnable structure: token t+1 correlates with token t
        mask = rng.random((self.local_batch, c.seq_len + 1)) < 0.5
        shifted = (base + self.shift[step % 64]) % c.vocab_size
        seq = np.where(mask, shifted, base).astype(np.int32)
        return seq[:, :-1], seq[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread double buffering (overlap data gen with compute)."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)
