from repro.data.pipeline import DataConfig, SyntheticLMStream, PrefetchIterator
