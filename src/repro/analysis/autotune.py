"""Measured cost model + persistent tuning cache behind every dispatch.

The dispatch layer (``core/linear_solve._resolve_auto`` and
``_upgrade_for_sharded``, ``launch/mesh.auto_mesh_size``, the Pallas
``batched_cg(block_b="auto")`` schedule) used to choose on structure
alone; BENCH_smoke.json showed that leaving large factors on the table
(sharded 1.44x SLOWER than single-device at mesh=8, B=64, d=16).  This
module makes every such decision empirical:

  * ``TuningCache`` — a persistent map from a dispatch regime
    ``TuningKey(backend, solver, B, d, dtype, mesh_size, precond,
    variant)`` to a measured (or modeled) solve time.  Versioned JSON
    ``save``/``load`` mirrors the ``WarmStartCache._SAVE_VERSION``
    pattern; ``REPRO_AUTOTUNE_CACHE`` pre-loads the process default, so a
    deployment ships a pre-tuned cache as a file.
  * measurement — ``measure_solver`` / ``measure_block_schedule`` run
    timed candidate micro-benchmarks (median-of-k, jit-warmup excluded)
    and record them; ``benchmarks/autotune_sweep.py`` drives them
    offline.  Measurement NEVER happens inside dispatch: decisions are
    made at trace time from the cache, populated on demand from host
    code or offline sweeps.
  * prediction — ``predict_solve_seconds`` returns the measured entry
    when one exists and otherwise falls back to the roofline solve model
    (``analysis/roofline.analyze_solve``).  Costs are only ever compared
    LIKE-FOR-LIKE: measured against measured, roofline against roofline
    (a TPU-model estimate and a wall-clock median are different units).
  * decisions — ``should_shard`` (gates the sharded-solver upgrade at
    the operand's mesh size), ``auto_mesh_size`` (picks the mesh extent
    instead of blindly using all devices) and ``choose_block_b`` (the
    tuned Pallas tile height behind ``block_b="auto"``).

Cold-cache semantics: with no measurements the roofline fallback
predicts a win for pure batch sharding at any extent (per-chip work
divides by the mesh, no collectives), so structural behavior is
unchanged until measurements say otherwise — host-side dispatch
overhead, the cause of the mesh=8 regression, is exactly what measured
entries capture and the hardware model deliberately omits.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Callable, Dict, Iterable, List, NamedTuple, Optional, Tuple

from repro.observability import metrics as obs_metrics

# NOTE: repro.core / repro.distributed / repro.launch are imported lazily
# inside functions — linear_solve consults this module at dispatch time,
# so a top-level import either way would cycle.  repro.observability is
# bottom-adjacent (imports nothing from repro), so it is safe up here;
# the decision counters below are always-on host-side bookkeeping, not
# gated telemetry — recording WHY dispatch chose a path costs one dict
# lookup and never touches the device.

_SHARD_ACCEPT_SLACK = 1.05   # shard when predicted <= single * slack


class TuningKey(NamedTuple):
    """One dispatch regime: everything a timing is conditioned on.

    ``backend`` is the jax backend the measurement ran on (timings never
    transfer across backends), ``solver`` a registry name (or
    ``"batched_cg"`` for kernel-schedule entries), ``B``/``d``/``dtype``
    the batched-system shape, ``mesh_size`` the 1-D solve-mesh extent
    (1 = single device), ``precond`` the normalized preconditioner tag
    ("" for none) and ``variant`` a free-form schedule qualifier (e.g.
    ``"block_b=16"``).
    """
    backend: str
    solver: str
    B: int
    d: int
    dtype: str = "float32"
    mesh_size: int = 1
    precond: str = ""
    variant: str = ""


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """A cached cost: ``seconds`` per solve, its ``source`` (``"measured"``
    or ``"roofline"``) and how many timed ``samples`` produced it."""
    seconds: float
    source: str = "measured"
    samples: int = 0


def normalize_precond(precond) -> str:
    """Fold a ``precond`` argument to its cache-key tag ("" for none)."""
    if precond is None:
        return ""
    if isinstance(precond, str):
        return precond
    return "callable"


def current_backend() -> str:
    """The jax backend dispatch decisions are conditioned on."""
    import jax
    return jax.default_backend()


class TuningCache:
    """Thread-safe store of ``TuningKey -> TuningRecord`` with versioned
    persistence (the ``WarmStartCache`` save/load pattern, JSON-encoded
    since entries are scalars, not arrays)."""

    _SAVE_VERSION = 1

    def __init__(self):
        self._mutex = threading.Lock()
        self._store: Dict[TuningKey, TuningRecord] = {}

    def put(self, key: TuningKey, seconds: float, *,
            source: str = "measured", samples: int = 1) -> TuningRecord:
        """Insert/overwrite the cost record for ``key``."""
        rec = TuningRecord(seconds=float(seconds), source=str(source),
                           samples=int(samples))
        with self._mutex:
            self._store[TuningKey(*key)] = rec
        obs_metrics.global_registry().counter(
            "repro_autotune_cache_puts_total",
            help="tuning-cache inserts by record source",
            source=rec.source).inc()
        return rec

    def get(self, key: TuningKey) -> Optional[TuningRecord]:
        """The record for ``key``, or None when never tuned."""
        with self._mutex:
            return self._store.get(TuningKey(*key))

    def lookup(self, **fields) -> Optional[TuningRecord]:
        """Keyword-style ``get`` (defaults fill unspecified key fields)."""
        return self.get(TuningKey(**fields))

    def __len__(self) -> int:
        with self._mutex:
            return len(self._store)

    def __contains__(self, key: TuningKey) -> bool:
        return self.get(key) is not None

    def items(self) -> List[Tuple[TuningKey, TuningRecord]]:
        """A stable snapshot of all entries (sorted by key)."""
        with self._mutex:
            return sorted(self._store.items())

    def save(self, path) -> str:
        """Persist all entries to ``path`` as version-stamped JSON.

        Layout: ``{"format_version": 1, "entries": [{<key fields>,
        "seconds", "source", "samples"}, ...]}``.  Returns the path
        written (``.json`` appended when missing).
        """
        path = str(path)
        if not path.endswith(".json"):
            path += ".json"
        entries = [{**k._asdict(), **dataclasses.asdict(r)}
                   for k, r in self.items()]
        with open(path, "w") as f:
            json.dump({"format_version": self._SAVE_VERSION,
                       "entries": entries}, f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path) -> "TuningCache":
        """Restore a cache written by ``save``; rejects unknown versions."""
        with open(str(path)) as f:
            blob = json.load(f)
        version = int(blob.get("format_version", -1))
        if version != cls._SAVE_VERSION:
            raise ValueError(
                f"tuning cache file {str(path)!r} has format version "
                f"{version}; this build reads version {cls._SAVE_VERSION}")
        cache = cls()
        for e in blob["entries"]:
            key = TuningKey(**{f: e[f] for f in TuningKey._fields})
            cache.put(key, e["seconds"], source=e["source"],
                      samples=e["samples"])
        return cache


# ---------------------------------------------------------------------------
# the process-default cache
# ---------------------------------------------------------------------------

_DEFAULT_CACHE: Optional[TuningCache] = None
_DEFAULT_MUTEX = threading.Lock()

#: environment variable naming a ``TuningCache.save`` file to pre-load as
#: the process default — how a deployment ships a pre-tuned cache.
CACHE_ENV_VAR = "REPRO_AUTOTUNE_CACHE"


def default_cache() -> TuningCache:
    """The process-wide cache every dispatch decision consults.

    Created empty on first use — unless ``REPRO_AUTOTUNE_CACHE`` names a
    readable ``TuningCache.save`` file, which is loaded instead.
    """
    global _DEFAULT_CACHE
    with _DEFAULT_MUTEX:
        if _DEFAULT_CACHE is None:
            path = os.environ.get(CACHE_ENV_VAR, "")
            if path and os.path.exists(path):
                _DEFAULT_CACHE = TuningCache.load(path)
            else:
                _DEFAULT_CACHE = TuningCache()
        return _DEFAULT_CACHE


def set_default_cache(cache: Optional[TuningCache]) -> Optional[TuningCache]:
    """Replace the process-default cache; returns the previous one.

    ``None`` resets to lazy re-initialization (re-reading the env var).
    """
    global _DEFAULT_CACHE
    with _DEFAULT_MUTEX:
        prev, _DEFAULT_CACHE = _DEFAULT_CACHE, cache
    return prev


@contextlib.contextmanager
def use_cache(cache: TuningCache):
    """Scope ``cache`` as the process default (tests seed decisions so)."""
    prev = set_default_cache(cache)
    try:
        yield cache
    finally:
        set_default_cache(prev)


# ---------------------------------------------------------------------------
# measurement (median-of-k, warmup excluded)
# ---------------------------------------------------------------------------

def measure(fn: Callable[[], object], *, warmup: int = 1,
            iters: int = 5) -> float:
    """Median wall-clock seconds of ``fn()`` over ``iters`` timed runs.

    ``warmup`` untimed calls run first, so jit compilation never counts;
    results with a ``block_until_ready`` method are synchronized inside
    the timed region (async dispatch would otherwise hide the work).
    """
    import statistics

    def _run():
        out = fn()
        block = getattr(out, "block_until_ready", None)
        if block is not None:
            block()
        return out

    for _ in range(max(warmup, 0)):
        _run()
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        _run()
        samples.append(time.perf_counter() - t0)
    return float(statistics.median(samples))


def _synthetic_spd(B: int, d: int, dtype: str, seed: int = 0):
    """A well-conditioned random SPD batch (B, d, d) + rhs (B, d)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    C = rng.randn(B, d, d) / np.sqrt(d)
    A = np.einsum("bji,bjk->bik", C, C) + 0.5 * np.eye(d)
    b = rng.randn(B, d)
    # cast LAST: NumPy-2 scalar promotion would float64 the intermediate
    return A.astype(dtype), b.astype(dtype)


def measure_solver(solver: str, B: int, d: int, *, dtype: str = "float32",
                   mesh_size: int = 1, precond=None,
                   cache: Optional[TuningCache] = None, tol: float = 1e-6,
                   maxiter: int = 200, warmup: int = 1, iters: int = 5,
                   seed: int = 0) -> TuningRecord:
    """Micro-benchmark one registry solver on a synthetic SPD regime and
    record the median into the cache.

    ``sharded_*`` solvers run on a fresh 1-D mesh of ``mesh_size`` local
    devices with the batch axis sharded (the production hypergradient
    layout); everything else runs single-device on a ``DenseOperator``.
    The timed call is jitted, so the median captures steady-state
    execution (shard_map dispatch overhead included — the quantity the
    mesh cost model exists to observe) while compilation lands in the
    warmup.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import linear_solve as ls
    from repro.core import operators as ops

    cache = cache if cache is not None else default_cache()
    A_np, b_np = _synthetic_spd(B, d, dtype, seed)
    A = jnp.asarray(A_np)
    b = jnp.asarray(b_np)
    base = ops.DenseOperator(A, positive_definite=True)
    if solver.startswith("sharded_"):
        from repro.distributed.sharded_operators import ShardedOperator
        from repro.launch.mesh import make_solve_mesh
        mesh = make_solve_mesh(devices=int(mesh_size))
        op = ShardedOperator(base, mesh, P("data", None))
    else:
        if mesh_size != 1:
            raise ValueError(f"single-device solver {solver!r} cannot be "
                             f"measured at mesh_size={mesh_size}")
        op = base

    fn = jax.jit(lambda rhs: ls.solve(op, rhs, method=solver, tol=tol,
                                      maxiter=maxiter))
    seconds = measure(lambda: fn(b), warmup=warmup, iters=iters)
    key = TuningKey(current_backend(), solver, int(B), int(d), dtype,
                    int(mesh_size), normalize_precond(precond))
    return cache.put(key, seconds, source="measured", samples=iters)


def block_b_candidates(B: int) -> List[int]:
    """Power-of-two tile heights that divide ``B`` (the sweep grid)."""
    out = [bb for bb in (1, 2, 4, 8, 16, 32, 64) if bb <= B and B % bb == 0]
    return out or [1]


def measure_block_schedule(B: int, d: int, *, dtype: str = "float32",
                           candidates: Optional[Iterable[int]] = None,
                           interpret: bool = True,
                           cache: Optional[TuningCache] = None,
                           tol: float = 1e-6, warmup: int = 1,
                           iters: int = 3, seed: int = 0) \
        -> Dict[int, TuningRecord]:
    """Sweep the Pallas batched-CG ``(block_b, lanes-padded d')`` schedule
    at one ``(B, d)`` point and record each candidate.

    Entries are keyed ``solver="batched_cg"``, ``variant="block_b=<k>"``.
    On non-TPU backends the sweep runs the kernel in interpret mode
    (``interpret=True``), where ``block_b`` controls the emulated grid's
    program count — the same schedule trade-off the compiled kernel has,
    observable without hardware; on TPU pass ``interpret=False`` to time
    the real kernel.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.batched_cg.ops import batched_cg

    cache = cache if cache is not None else default_cache()
    A_np, b_np = _synthetic_spd(B, d, dtype, seed)
    A = jnp.asarray(A_np)
    b = jnp.asarray(b_np)
    out: Dict[int, TuningRecord] = {}
    for bb in (candidates if candidates is not None
               else block_b_candidates(B)):
        fn = jax.jit(lambda rhs, bb=bb: batched_cg(
            A, rhs, tol=tol, block_b=bb, interpret=interpret))
        seconds = measure(lambda: fn(b), warmup=warmup, iters=iters)
        key = TuningKey(current_backend(), "batched_cg", int(B), int(d),
                        dtype, 1, "", f"block_b={int(bb)}")
        out[int(bb)] = cache.put(key, seconds, source="measured",
                                 samples=iters)
    return out


# ---------------------------------------------------------------------------
# prediction (measured first, roofline fallback)
# ---------------------------------------------------------------------------

def _dtype_bytes(dtype: str) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize)


def roofline_solve_seconds(B: int, d: int, *, dtype: str = "float32",
                           mesh_size: int = 1,
                           instance_sharded: bool = False) -> float:
    """The cold-cache estimate: ``roofline.analyze_solve`` step time."""
    from repro.analysis import roofline
    terms = roofline.analyze_solve(int(B), int(d),
                                   dtype_bytes=_dtype_bytes(dtype),
                                   mesh_size=int(mesh_size),
                                   instance_sharded=bool(instance_sharded))
    return terms.step_time_s


def predict_solve_seconds(solver: str, B: int, d: int, *,
                          dtype: str = "float32", mesh_size: int = 1,
                          precond=None, instance_sharded: bool = False,
                          cache: Optional[TuningCache] = None,
                          backend: Optional[str] = None) \
        -> Tuple[float, str]:
    """Predicted seconds for one solve and the prediction's source.

    Returns ``(seconds, "measured")`` when the cache holds a measurement
    for this exact regime, else ``(seconds, "roofline")`` from the
    hardware model.  Callers comparing candidates must compare like
    sources only — see ``should_shard``.
    """
    cache = cache if cache is not None else default_cache()
    key = TuningKey(backend or current_backend(), solver, int(B), int(d),
                    dtype, int(mesh_size), normalize_precond(precond))
    rec = cache.get(key)
    counter = obs_metrics.global_registry().counter
    if rec is not None and rec.source == "measured":
        counter("repro_autotune_predictions_total",
                help="cost predictions by source", source="measured").inc()
        return rec.seconds, "measured"
    counter("repro_autotune_predictions_total",
            help="cost predictions by source", source="roofline").inc()
    return roofline_solve_seconds(
        B, d, dtype=dtype, mesh_size=mesh_size,
        instance_sharded=instance_sharded), "roofline"


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------

def single_device_solver(spd: bool, d: int, plain: bool = True) -> str:
    """The single-device registry solver a regime would route to — the
    comparison point for every sharding decision (mirrors the dense /
    matrix-free split in ``linear_solve._resolve_auto``)."""
    from repro.core import linear_solve as ls
    if d <= ls.MAX_DENSE_DIM:
        return "pallas_cg" if (spd and plain) else "dense_gmres"
    return "cg" if spd else "normal_cg"


def should_shard(B: int, d: int, *, mesh_size: int,
                 instance_sharded: bool = False, spd: bool = True,
                 dtype: str = "float32", precond=None, plain: bool = True,
                 cache: Optional[TuningCache] = None,
                 backend: Optional[str] = None) -> bool:
    """True when the cost model predicts the sharded solver wins (within
    5% slack) over the single-device path at this operand's mesh size.

    ``mesh_size <= 1`` always shards (a 1-device mesh is the
    single-device path under shard_map, and refusing it would make local
    runs diverge from their own placement declarations).  Otherwise the
    sharded candidate (``sharded_cg`` for SPD, ``sharded_normal_cg``
    else) is compared against ``single_device_solver``'s pick —
    measured-vs-measured when the cache holds BOTH sides, otherwise
    roofline-vs-roofline.  A cold cache therefore keeps structural
    behavior (the hardware model has batch sharding dividing per-chip
    work with zero communication) until measurements prove a regime
    loses — which is how the B=64/d=16 mesh=8 oversharding gets refused.
    """
    counter = obs_metrics.global_registry().counter

    def _decide(shard: bool, basis: str) -> bool:
        counter("repro_autotune_shard_decisions_total",
                help="sharding decisions by outcome and evidence basis",
                decision="shard" if shard else "single",
                basis=basis).inc()
        return shard

    if mesh_size <= 1:
        return _decide(True, "trivial")
    cache = cache if cache is not None else default_cache()
    backend = backend or current_backend()
    sharded = "sharded_cg" if spd else "sharded_normal_cg"
    single = single_device_solver(spd, d, plain)
    pc = normalize_precond(precond)
    rec_sh = cache.get(TuningKey(backend, sharded, int(B), int(d), dtype,
                                 int(mesh_size), pc))
    rec_si = cache.get(TuningKey(backend, single, int(B), int(d), dtype,
                                 1, pc))
    if rec_sh is not None and rec_si is not None:
        t_sh, t_si = rec_sh.seconds, rec_si.seconds
        basis = "measured"
    else:
        t_sh = roofline_solve_seconds(B, d, dtype=dtype,
                                      mesh_size=mesh_size,
                                      instance_sharded=instance_sharded)
        t_si = roofline_solve_seconds(B, d, dtype=dtype, mesh_size=1)
        basis = "roofline"
    return _decide(t_sh <= t_si * _SHARD_ACCEPT_SLACK, basis)


def mesh_candidates(B: int, max_devices: Optional[int] = None) -> List[int]:
    """Power-of-two mesh extents that divide ``B`` and fit the device
    count (1 is always a candidate)."""
    import jax
    cap = len(jax.devices()) if max_devices is None else int(max_devices)
    out = [m for m in (1, 2, 4, 8, 16, 32, 64, 128)
           if m <= cap and m <= B and B % m == 0]
    return out or [1]


def auto_mesh_size(B: int, d: int, *, max_devices: Optional[int] = None,
                   spd: bool = True, dtype: str = "float32",
                   instance_sharded: bool = False, precond=None,
                   cache: Optional[TuningCache] = None,
                   backend: Optional[str] = None) -> int:
    """The mesh extent the cost model picks for a (B, d) solve regime.

    Candidates are power-of-two extents dividing ``B`` up to the local
    device count (or ``max_devices``).  When ANY candidate has a
    measured cache entry the argmin runs over measured candidates only
    (a measurement always outranks a model); a fully cold cache falls
    back to the roofline argmin, which for batch sharding selects the
    largest extent — exactly the old all-devices behavior until
    measurements exist.  Ties break toward the smaller mesh.
    """
    cache = cache if cache is not None else default_cache()
    backend = backend or current_backend()
    solver = "sharded_cg" if spd else "sharded_normal_cg"
    pc = normalize_precond(precond)
    measured: Dict[int, float] = {}
    modeled: Dict[int, float] = {}
    for m in mesh_candidates(B, max_devices):
        rec = cache.get(TuningKey(backend, solver, int(B), int(d), dtype,
                                  int(m), pc))
        if rec is not None and rec.source == "measured":
            measured[m] = rec.seconds
        modeled[m] = roofline_solve_seconds(
            B, d, dtype=dtype, mesh_size=m,
            instance_sharded=instance_sharded)
    pool = measured if measured else modeled
    return min(sorted(pool), key=lambda m: (pool[m], m))


def default_block_b(B: int, d: int, *, dtype: str = "float32",
                    pad_lanes: bool = False) -> int:
    """The untuned tile height: the legacy default 8, shrunk to divide
    ``B`` and to keep the (block_b, d', d') operator tile inside a
    conservative VMEM budget (~4 MiB)."""
    lanes = 128
    dp = ((d + lanes - 1) // lanes) * lanes if pad_lanes else d
    budget = 4 * 1024 * 1024
    bb = 8
    while bb > 1 and bb * dp * dp * _dtype_bytes(dtype) > budget:
        bb //= 2
    bb = min(bb, B)
    while B % bb:
        bb -= 1
    return max(bb, 1)


def choose_block_b(B: int, d: int, *, dtype: str = "float32",
                   pad_lanes: bool = False,
                   cache: Optional[TuningCache] = None,
                   backend: Optional[str] = None) -> int:
    """The tuned Pallas batched-CG tile height for ``block_b="auto"``.

    Picks the fastest measured ``variant="block_b=<k>"`` entry for this
    ``(backend, B, d, dtype)`` regime (populated by
    ``measure_block_schedule`` / the offline sweep); with no
    measurements, falls back to ``default_block_b`` — i.e. the legacy
    hardcoded schedule, so ``"auto"`` is never worse than the old
    default.
    """
    cache = cache if cache is not None else default_cache()
    backend = backend or current_backend()
    measured: Dict[int, float] = {}
    for bb in block_b_candidates(B):
        rec = cache.get(TuningKey(backend, "batched_cg", int(B), int(d),
                                  dtype, 1, "", f"block_b={bb}"))
        if rec is not None and rec.source == "measured":
            measured[bb] = rec.seconds
    if measured:
        return min(sorted(measured), key=lambda bb: (measured[bb], bb))
    return default_block_b(B, d, dtype=dtype, pad_lanes=pad_lanes)


def operator_regime(A) -> Tuple[int, int, str]:
    """(B, d, dtype) of a ``LinearOperator``'s example — the dispatch
    regime key.  Batch-aware operators (``batch_ndim == 1``) read B off
    the leading axis; unbatched operators are B=1 with d the full raveled
    size."""
    import jax
    leaves = jax.tree_util.tree_leaves(A.example)
    if not leaves:
        return 1, 1, "float32"
    dtype = str(leaves[0].dtype)
    n = int(sum(leaf.size for leaf in leaves))
    if getattr(A, "batch_ndim", 0) == 1:
        Bn = int(leaves[0].shape[0])
        return Bn, max(n // max(Bn, 1), 1), dtype
    return 1, n, dtype
