"""Loop-aware HLO analysis: FLOPs, HBM bytes and collective bytes from the
compiled (SPMD-partitioned) module text.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits each
``while`` body ONCE — but our production programs put the layer stack and the
microbatch loop inside ``lax.scan``, so the reported FLOPs under-count by the
product of trip counts (~640× for a 40-layer, 16-microbatch step).  This
module parses the HLO text into computations, resolves ``fusion``/``call``/
``while`` call graphs, extracts scan trip counts from the loop-condition
constants, and multiplies.

Cost model (per instruction, post-partition = per-device shapes):
  * ``dot``: 2 · numel(out) · K  (K = contracted extent from operand shape)
  * ``convolution``: 2 · numel(out) · prod(kernel spatial) · C_in
  * HBM bytes: Σ operand bytes + output bytes at FUSION boundaries (fusion
    internals live in registers/VMEM — this is exactly the TPU HBM model);
    non-fused ops count their own operands + outputs.
  * collectives: operand bytes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute (the payload each device puts on the
    wire), loop-multiplied like everything else.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# result type: either a (one-level) tuple type or one token + optional layout
_OP_RE = re.compile(
    r"^(\((?:[^()])*\)|[^\s(]+(?:\{[^}]*\})?)\s+([a-z][\w\-]*)\(")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list
    operand_shapes: list
    operand_names: List[str]
    called: List[str]
    cond: Optional[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def _split_result_args(rhs: str):
    """rhs: 'bf16[8,128]{1,0} dot(bf16[8,64] %a, bf16[64,128] %b), meta...'
    Returns (result_text, opcode, args_text, meta_text)."""
    m = _OP_RE.match(rhs)
    if m is None:
        return rhs, None, "", ""
    result_text, opcode = m.group(1), m.group(2)
    rest = rhs[m.end() - 1:]
    depth, end = 0, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return result_text, opcode, rest[1:end], rest[end + 1:]


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}" or line.strip().startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im is None:
            continue
        name, rhs = im.group(1), im.group(2)
        result_text, opcode, args, meta = _split_result_args(rhs)
        if opcode is None:
            continue
        called = _CALLED_RE.findall(meta) + _CALLED_RE.findall(args)
        condm = _COND_RE.search(meta) or _COND_RE.search(args)
        instr = Instr(
            name=name, opcode=opcode,
            result_shapes=_shape_list(result_text),
            operand_shapes=_shape_list(args),
            operand_names=_OPERAND_NAME_RE.findall(args),
            called=called,
            cond=condm.group(1) if condm else None,
            line=line)
        cur.instrs.append(instr)
    # resolve operand shapes from each computation's symbol table (compiled
    # HLO references operands by %name without inline types)
    for comp in comps.values():
        table = {i.name: i.result_shapes for i in comp.instrs}
        for ins in comp.instrs:
            if not ins.operand_shapes and ins.operand_names:
                resolved = []
                for nm in ins.operand_names:
                    resolved.extend(table.get(nm, []))
                ins.operand_shapes = resolved
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Scan loops lower to `while(i < N)`; N is a constant in the condition
    computation.  Heuristic: the largest integer constant found there."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        for m in _CONST_RE.finditer(ins.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr) -> float:
    out_elems = 0
    for dtype, dims in ins.result_shapes:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    if not ins.operand_shapes:
        return 0.0
    # contracted extent K: prod(lhs dims) * prod(rhs dims) / out / batch²…
    # robust route: K = numel(lhs) * numel(rhs) / (out * numel(batch dims)²)
    # simpler: parse lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    lhs = ins.operand_shapes[0][1]
    k = 1
    if mc:
        for i in mc.group(1).split(","):
            if i:
                k *= lhs[int(i)]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr) -> float:
    out_elems = 0
    for dtype, dims in ins.result_shapes:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    if len(ins.operand_shapes) < 2:
        return 2.0 * out_elems
    kern = ins.operand_shapes[1][1]
    kn = 1
    for d in kern:
        kn *= d
    # kernel numel includes C_in·C_out; divide C_out (≈ last dim of out)
    cout = ins.result_shapes[0][1][-1] if ins.result_shapes and \
        ins.result_shapes[0][1] else 1
    return 2.0 * out_elems * max(kn // max(cout, 1), 1)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_ops: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] += v * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] += int(v * mult)


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy", "while", "conditional", "call",
                   "custom-call", "after-all", "partition-id", "replica-id"}


def analyze_computation(comps: Dict[str, Computation], name: str,
                        memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()          # break recursion
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = Costs()
    for ins in comp.instrs:
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES and not op.endswith("-done"):
            b = _bytes_of(ins.operand_shapes or ins.result_shapes)
            total.collective_bytes += b
            total.per_collective[base] += b
            total.collective_ops[base] += 1
            total.hbm_bytes += b + _bytes_of(ins.result_shapes)
        elif op == "dot":
            total.flops += _dot_flops(ins)
            total.hbm_bytes += _bytes_of(ins.operand_shapes) + \
                _bytes_of(ins.result_shapes)
        elif op == "convolution":
            total.flops += _conv_flops(ins)
            total.hbm_bytes += _bytes_of(ins.operand_shapes) + \
                _bytes_of(ins.result_shapes)
        elif op == "fusion":
            inner = analyze_computation(comps, ins.called[0], memo) \
                if ins.called else Costs()
            # fusion: internals stay on-chip; HBM traffic = boundary only
            total.flops += inner.flops
            total.collective_bytes += inner.collective_bytes
            for k, v in inner.per_collective.items():
                total.per_collective[k] += v
            for k, v in inner.collective_ops.items():
                total.collective_ops[k] += v
            total.hbm_bytes += _bytes_of(ins.operand_shapes) + \
                _bytes_of(ins.result_shapes)
        elif op == "while":
            body = ins.called[0] if ins.called else None
            trip = _trip_count(comps, ins.cond) if ins.cond else 1
            if body:
                inner = analyze_computation(comps, body, memo)
                total.add(inner, mult=trip)
        elif op in ("call", "conditional", "async-start"):
            for c in ins.called:
                total.add(analyze_computation(comps, c, memo))
        elif op in _SKIP_BYTES_OPS:
            continue
        else:
            # elementwise / reduce / reshape etc. outside fusions
            total.hbm_bytes += _bytes_of(ins.operand_shapes) + \
                _bytes_of(ins.result_shapes)
    memo[name] = total
    return total


def _entry_name(comps: Dict[str, Computation], hlo_text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation that is not called by anyone
    called = set()
    for c in comps.values():
        for ins in c.instrs:
            called.update(ins.called)
            if ins.cond:
                called.add(ins.cond)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def analyze_module(hlo_text: str) -> Costs:
    comps = parse_module(hlo_text)
    if not comps:
        return Costs()
    entry = _entry_name(comps, hlo_text)
    return analyze_computation(comps, entry, {})


# -- compatibility helpers (older call sites / tests) -----------------------

def collective_bytes(hlo_text: str) -> Dict[str, int]:
    c = analyze_module(hlo_text)
    out = {k: int(v) for k, v in c.per_collective.items()}
    out["total"] = int(c.collective_bytes)
    return out


def collective_op_counts(hlo_text: str) -> Dict[str, int]:
    return dict(analyze_module(hlo_text).collective_ops)
