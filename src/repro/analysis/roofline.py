"""Roofline model for TPU v5e (assignment hardware constants).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips × 819e9  B/s HBM)
    collective = coll_bytes  / (chips × 50e9   B/s per ICI link)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

# Iterative-solve extension (the autotune layer's cold-cache estimate).
# A psum over instance-sharding axes is latency-bound at solver scales
# (two scalar reductions per CG iteration), so it is modeled as a fixed
# per-iteration latency rather than ICI bytes.  Pure *batch* sharding has
# no cross-device communication at all (the reduce hook is the identity);
# its real-world overhead is host-side dispatch, which the roofline
# deliberately omits — that regime is what measured cache entries are for.
PSUM_LATENCY_S = 1e-6


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float
    chips: int
    # per-iteration time of an iterative solve (0.0 for the step-level
    # ``analyze`` path; set by ``analyze_solve``)
    solve_iteration_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic overlap model: max of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-predicted step time."""
        t = self.step_time_s
        if t == 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "step_time_s": self.step_time_s,
                "mfu": self.mfu}


def analyze(cost: Dict, coll_bytes: float, chips: int,
            model_flops: float) -> RooflineTerms:
    """``cost``: compiled.cost_analysis() dict (flops / bytes accessed)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # cost_analysis is per-module over all devices' program: on SPMD-
    # partitioned modules XLA reports the PER-DEVICE program cost.
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll_bytes,
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        chips=chips)


def expected_solve_iters(d: int) -> int:
    """Expected Krylov iteration count for a d-dim system.

    CG terminates in at most ``d`` exact-arithmetic steps; at the
    moderate conditioning the dispatch regimes care about, convergence to
    typical tolerances takes O(sqrt(kappa)) iterations, which we proxy as
    ``2·sqrt(d)`` with a floor of 8 (setup iterations dominate tiny
    systems).
    """
    import math
    return int(min(d, max(8, round(2.0 * math.sqrt(d)))))


def analyze_solve(B: int, d: int, *, dtype_bytes: int = 4,
                  iters: int = None, mesh_size: int = 1,
                  instance_sharded: bool = False) -> RooflineTerms:
    """Roofline estimate for one batched iterative solve (B systems, dim d).

    Per iteration, each instance performs one dense-equivalent matvec
    (2·d² FLOPs, d²·dtype_bytes operator bytes) plus O(d) vector updates;
    a mesh of ``mesh_size`` chips divides the batch work evenly.  Sharded
    *instance* dims add one latency-bound ``psum`` per iteration
    (``PSUM_LATENCY_S``); pure batch sharding communicates nothing.  The
    returned terms describe the WHOLE solve (``iters`` iterations,
    defaulting to ``expected_solve_iters(d)``), with the per-iteration
    time in ``solve_iteration_s``.  This is the autotune layer's
    cold-cache fallback: relative, not absolute — host-side dispatch
    overheads are out of model and belong to measured cache entries.
    """
    if iters is None:
        iters = expected_solve_iters(d)
    iters = max(int(iters), 1)
    chips = max(int(mesh_size), 1)
    flops_iter = B * (2.0 * d * d + 6.0 * d)
    bytes_iter = B * (d * d + 6.0 * d) * float(dtype_bytes)
    # per-device program cost, mirroring ``analyze``'s SPMD convention
    per_chip_flops = iters * flops_iter / chips
    per_chip_bytes = iters * bytes_iter / chips
    compute_s = per_chip_flops / PEAK_FLOPS
    memory_s = per_chip_bytes / HBM_BW
    collective_s = (iters * PSUM_LATENCY_S
                    if (instance_sharded and chips > 1) else 0.0)
    model_flops = iters * 2.0 * B * d * d
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        hlo_flops=per_chip_flops,
        hlo_bytes=per_chip_bytes,
        collective_bytes=0.0,
        model_flops=model_flops,
        useful_ratio=model_flops / (per_chip_flops * chips),
        chips=chips,
        solve_iteration_s=max(compute_s, memory_s, collective_s) / iters)


def model_flops_train(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: float, tokens: float) -> float:
    return 2.0 * n_active_params * tokens
