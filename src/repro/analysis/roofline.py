"""Roofline model for TPU v5e (assignment hardware constants).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips × 819e9  B/s HBM)
    collective = coll_bytes  / (chips × 50e9   B/s per ICI link)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic overlap model: max of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-predicted step time."""
        t = self.step_time_s
        if t == 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "step_time_s": self.step_time_s,
                "mfu": self.mfu}


def analyze(cost: Dict, coll_bytes: float, chips: int,
            model_flops: float) -> RooflineTerms:
    """``cost``: compiled.cost_analysis() dict (flops / bytes accessed)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # cost_analysis is per-module over all devices' program: on SPMD-
    # partitioned modules XLA reports the PER-DEVICE program cost.
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll_bytes,
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        chips=chips)


def model_flops_train(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: float, tokens: float) -> float:
    return 2.0 * n_active_params * tokens
