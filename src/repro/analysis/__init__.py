from repro.analysis import autotune, hlo, roofline
