from repro.analysis import hlo, roofline
