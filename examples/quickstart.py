"""Quickstart — the paper's Figure 1, verbatim shape.

Add implicit differentiation on top of a ridge-regression solver with one
decorator, then take Jacobians through the solver with plain jax.jacobian.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import custom_root

jax.config.update("jax_enable_x64", True)

key = jax.random.PRNGKey(0)
X_train = jax.random.normal(key, (50, 8))
y_train = jax.random.normal(jax.random.fold_in(key, 1), (50,))


def f(x, theta):   # objective function
    residual = jnp.dot(X_train, x) - y_train
    return (jnp.sum(residual ** 2) + theta * jnp.sum(x ** 2)) / 2


# Since f is differentiable and unconstrained, the optimality condition F is
# simply the gradient of f in the first argument (paper eq. 4).
F = jax.grad(f, argnums=0)


@custom_root(F)
def ridge_solver(init_x, theta):
    del init_x   # initialization not used in this solver
    XX = jnp.dot(X_train.T, X_train)
    Xy = jnp.dot(X_train.T, y_train)
    I = jnp.eye(X_train.shape[1])
    return jnp.linalg.solve(XX + theta * I, Xy)


if __name__ == "__main__":
    init_x = None
    J = jax.jacobian(ridge_solver, argnums=1)(init_x, 10.0)
    print("dx*/dtheta at theta=10:")
    print(J)

    # sanity: closed form ∂x*(θ) = −(XᵀX + θI)⁻² Xᵀy
    A = X_train.T @ X_train + 10.0 * jnp.eye(8)
    J_true = -jnp.linalg.solve(A, jnp.linalg.solve(A, X_train.T @ y_train))
    print("max |err| vs closed form:", float(jnp.max(jnp.abs(J - J_true))))
