"""Quickstart — the paper's Figure 1, plus the state-based solver runtime.

Part 1 is the paper's Fig. 1 verbatim shape: add implicit differentiation on
top of a ridge-regression solver with one decorator, then take Jacobians
through the solver with plain jax.jacobian.

Part 2 is the same problem through the solver runtime: construct a
``GradientDescent`` solver, call ``run()`` — implicit differentiation is
automatic (the solver declares its stationarity condition itself) and the
solve reports ``OptInfo`` diagnostics.

Part 3 is the mode-polymorphic API: one ``implicit_diff``-wrapped solver
(or the runtime's ``run()``) serves ``jax.jacrev`` AND ``jax.jacfwd``
without re-wrapping — pick the mode that matches your Jacobian shape.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (GradientDescent, ImplicitDiffSpec, custom_root,
                        implicit_diff)

jax.config.update("jax_enable_x64", True)

key = jax.random.PRNGKey(0)
X_train = jax.random.normal(key, (50, 8))
y_train = jax.random.normal(jax.random.fold_in(key, 1), (50,))


def f(x, theta):   # objective function
    residual = jnp.dot(X_train, x) - y_train
    return (jnp.sum(residual ** 2) + theta * jnp.sum(x ** 2)) / 2


# Since f is differentiable and unconstrained, the optimality condition F is
# simply the gradient of f in the first argument (paper eq. 4).
F = jax.grad(f, argnums=0)


@custom_root(F)
def ridge_solver(init_x, theta):
    del init_x   # initialization not used in this solver
    XX = jnp.dot(X_train.T, X_train)
    Xy = jnp.dot(X_train.T, y_train)
    I = jnp.eye(X_train.shape[1])
    return jnp.linalg.solve(XX + theta * I, Xy)


def closed_form_jacobian(theta):
    # ∂x*(θ) = −(XᵀX + θI)⁻² Xᵀy
    A = X_train.T @ X_train + theta * jnp.eye(X_train.shape[1])
    return -jnp.linalg.solve(A, jnp.linalg.solve(A, X_train.T @ y_train))


if __name__ == "__main__":
    # -- Part 1: the Fig. 1 decorator ------------------------------------
    J = jax.jacobian(ridge_solver, argnums=1)(None, 10.0)
    err = float(jnp.max(jnp.abs(J - closed_form_jacobian(10.0))))
    print("Part 1 (custom_root decorator)")
    print("  dx*/dtheta at theta=10:", J)
    print(f"  max |err| vs closed form: {err:.2e}")
    assert err < 1e-8

    # -- Part 2: the solver runtime --------------------------------------
    # Any IterativeSolver knows its own optimality mapping; run() attaches
    # implicit derivatives automatically and returns OptInfo diagnostics.
    # Lipschitz bound must cover the largest theta used below (θ = 100)
    L = float(jnp.linalg.eigvalsh(X_train.T @ X_train).max()) + 100.0
    solver = GradientDescent(f, stepsize=1.0 / L, maxiter=5000, tol=1e-12,
                             solve="cg")
    x_star, info = solver.run(jnp.zeros(8), 10.0)
    print("Part 2 (solver runtime)")
    print(f"  converged={bool(info.converged)} in {int(info.iterations)} "
          f"iterations, error={float(info.error):.2e}")
    assert bool(info.converged)

    J_rt = jax.jacobian(lambda t: solver.run(jnp.zeros(8), t)[0])(10.0)
    err_rt = float(jnp.max(jnp.abs(J_rt - closed_form_jacobian(10.0))))
    print(f"  max |err| vs closed form: {err_rt:.2e}")
    assert err_rt < 1e-6

    # the runtime is vmap-native: a batch of inner SOLVES is one masked
    # loop, and the batched gradient is ONE batched backward linear solve
    thetas = jnp.array([1.0, 10.0, 100.0])
    xs, infos = jax.vmap(lambda t: solver.run(jnp.zeros(8), t))(thetas)
    print(f"  vmapped solve: per-instance iterations = "
          f"{infos.iterations.tolist()}")
    assert bool(infos.converged.all())

    # -- Part 3: one wrapper, both autodiff modes ------------------------
    # The spec decouples the optimality condition from the differentiation
    # mechanism: the same wrapped solver takes reverse-mode (jacrev) and
    # forward-mode (jacfwd) Jacobians.  Forward mode costs one tangent
    # solve per parameter — the right choice when parameters are few and
    # outputs many (e.g. the MD sensitivity experiment).
    spec = ImplicitDiffSpec(optimality_fun=F, solve="cg", tol=1e-12)
    wrapped = implicit_diff(spec)(
        lambda init, t: jnp.linalg.solve(
            X_train.T @ X_train + t * jnp.eye(8), X_train.T @ y_train))
    J_rev = jax.jacrev(wrapped, argnums=1)(None, 10.0)
    J_fwd = jax.jacfwd(wrapped, argnums=1)(None, 10.0)
    agree = float(jnp.max(jnp.abs(J_rev - J_fwd)))
    print("Part 3 (mode-polymorphic implicit_diff)")
    print(f"  max |jacrev - jacfwd| on one wrapper: {agree:.2e}")
    assert agree < 1e-8
    # the runtime's run() is wrapped the same way: jacfwd works on it too
    J_fwd_rt = jax.jacfwd(lambda t: solver.run(jnp.zeros(8), t)[0])(10.0)
    assert float(jnp.max(jnp.abs(J_fwd_rt - J_rt))) < 1e-6
    print("OK")
