"""Molecular-dynamics sensitivity (paper §4.4, Figure 6) as a library user
would write it: FIRE minimization + forward-mode implicit differentiation of
particle positions with respect to particle diameter.

This is the canonical JVP-dominant workload — ONE scalar parameter, many
outputs (every particle coordinate) — so forward mode needs exactly one
tangent solve where reverse mode would need one cotangent solve per output.
Two equivalent routes are shown:

  1. the low-level ``root_jvp`` on the force residual at the FIRE minimum
     (the original Fig.-6 recipe);
  2. the solver runtime in forward mode: ``GradientDescent.run(...,
     mode="jvp")`` polishes the minimum and ``jax.jvp`` flows the diameter
     tangent through the implicit system automatically — no manual
     residual plumbing.

Run: PYTHONPATH=src python examples/md_sensitivity.py
"""
import jax
import jax.numpy as jnp

from benchmarks.molecular_dynamics import fire_minimize, pair_energy
from repro.core import GradientDescent, root_jvp

jax.config.update("jax_enable_x64", True)


def main():
    theta = 0.6
    x0 = jax.random.uniform(jax.random.PRNGKey(0), (32, 2))
    x_star = fire_minimize(x0, theta)

    def F(x, diameter):  # normalized forces (root at the minimum)
        return -jax.grad(lambda x: pair_energy(x, diameter))(x)

    dx = root_jvp(F, x_star, (theta,), (1.0,), solve="bicgstab",
                  tol=1e-8, ridge=1e-8)
    resid = float(jnp.linalg.norm(F(x_star, theta)))
    print(f"force residual at minimum: {resid:.2e}")
    print(f"position sensitivity ∂x*/∂θ: shape {dx.shape}, "
          f"L1 norm {float(jnp.sum(jnp.abs(dx))):.3f}")
    print("first 4 particles:")
    for i in range(4):
        print(f"  particle {i}: pos=({float(x_star[i,0]):.3f}, "
              f"{float(x_star[i,1]):.3f})  d pos/d θ=({float(dx[i,0]):+.4f},"
              f" {float(dx[i,1]):+.4f})")

    # -- the same sensitivity through the runtime, forward mode ----------
    # The solver declares its stationarity condition itself; run(mode="jvp")
    # wraps the solve so jax.jvp drives ONE tangent linear solve.  Warm-
    # started from the FIRE solution, the polish converges in a few steps.
    solver = GradientDescent(pair_energy, stepsize=2e-3, maxiter=2000,
                             tol=1e-10, solve="bicgstab", ridge=1e-8,
                             linsolve_tol=1e-8)

    def positions(diameter):
        return solver.run(x_star, diameter, mode="jvp")

    (x_rt, info), (dx_rt, _) = jax.jvp(positions, (theta,), (1.0,))
    drift = float(jnp.max(jnp.abs(dx_rt - dx)))
    print(f"runtime polish: converged={bool(info.converged)} in "
          f"{int(info.iterations)} steps")
    print(f"runtime forward-mode sensitivity: L1 norm "
          f"{float(jnp.sum(jnp.abs(dx_rt))):.3f}, "
          f"max |Δ| vs root_jvp = {drift:.2e}")
    assert drift < 1e-4, f"runtime JVP drifted from root_jvp: {drift}"
    print("OK")


if __name__ == "__main__":
    main()
