"""Molecular-dynamics sensitivity (paper §4.4, Figure 6) as a library user
would write it: FIRE minimization + forward-mode implicit differentiation of
particle positions with respect to particle diameter.

Run: PYTHONPATH=src python examples/md_sensitivity.py
"""
import jax
import jax.numpy as jnp

from benchmarks.molecular_dynamics import fire_minimize, pair_energy
from repro.core import root_jvp

jax.config.update("jax_enable_x64", True)


def main():
    theta = 0.6
    x0 = jax.random.uniform(jax.random.PRNGKey(0), (32, 2))
    x_star = fire_minimize(x0, theta)

    def F(x, diameter):  # normalized forces (root at the minimum)
        return -jax.grad(lambda x: pair_energy(x, diameter))(x)

    dx = root_jvp(F, x_star, (theta,), (1.0,), solve="bicgstab",
                  tol=1e-8, ridge=1e-8)
    resid = float(jnp.linalg.norm(F(x_star, theta)))
    print(f"force residual at minimum: {resid:.2e}")
    print(f"position sensitivity ∂x*/∂θ: shape {dx.shape}, "
          f"L1 norm {float(jnp.sum(jnp.abs(dx))):.3f}")
    print("first 4 particles:")
    for i in range(4):
        print(f"  particle {i}: pos=({float(x_star[i,0]):.3f}, "
              f"{float(x_star[i,1]):.3f})  d pos/d θ=({float(dx[i,0]):+.4f},"
              f" {float(dx[i,1]):+.4f})")
    print("OK")


if __name__ == "__main__":
    main()
