"""Molecular-dynamics sensitivity (paper §4.4, Figure 6) as a library user
would write it: FIRE minimization + forward-mode implicit differentiation of
particle positions with respect to particle diameter.

This is the canonical JVP-dominant workload — ONE scalar parameter, many
outputs (every particle coordinate) — so forward mode needs exactly one
tangent solve where reverse mode would need one cotangent solve per output.
Two equivalent routes are shown:

  1. the low-level ``root_jvp`` on the force residual at the FIRE minimum
     (the original Fig.-6 recipe);
  2. the solver runtime in forward mode: ``GradientDescent.run(...,
     mode="jvp")`` polishes the minimum and ``jax.jvp`` flows the diameter
     tangent through the implicit system automatically — no manual
     residual plumbing.

A third section sweeps the sensitivity over a BATCH of diameters and
solves all tangent systems on a mesh whose extent is picked by the
autotune cost model (``launch.auto_mesh_size``) — not hardcoded — so the
example demonstrates the tuned dispatch path end to end.

Run: PYTHONPATH=src python examples/md_sensitivity.py
"""
import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from benchmarks.molecular_dynamics import fire_minimize, pair_energy
from repro.core import GradientDescent, linear_solve, operators, root_jvp
from repro.distributed.sharded_operators import ShardedOperator
from repro.launch.mesh import auto_mesh_size, make_solve_mesh

jax.config.update("jax_enable_x64", True)


def main():
    theta = 0.6
    x0 = jax.random.uniform(jax.random.PRNGKey(0), (32, 2))
    x_star = fire_minimize(x0, theta)

    def F(x, diameter):  # normalized forces (root at the minimum)
        return -jax.grad(lambda x: pair_energy(x, diameter))(x)

    dx = root_jvp(F, x_star, (theta,), (1.0,), solve="bicgstab",
                  tol=1e-8, ridge=1e-8)
    resid = float(jnp.linalg.norm(F(x_star, theta)))
    print(f"force residual at minimum: {resid:.2e}")
    print(f"position sensitivity ∂x*/∂θ: shape {dx.shape}, "
          f"L1 norm {float(jnp.sum(jnp.abs(dx))):.3f}")
    print("first 4 particles:")
    for i in range(4):
        print(f"  particle {i}: pos=({float(x_star[i,0]):.3f}, "
              f"{float(x_star[i,1]):.3f})  d pos/d θ=({float(dx[i,0]):+.4f},"
              f" {float(dx[i,1]):+.4f})")

    # -- the same sensitivity through the runtime, forward mode ----------
    # The solver declares its stationarity condition itself; run(mode="jvp")
    # wraps the solve so jax.jvp drives ONE tangent linear solve.  Warm-
    # started from the FIRE solution, the polish converges in a few steps.
    solver = GradientDescent(pair_energy, stepsize=2e-3, maxiter=2000,
                             tol=1e-10, solve="bicgstab", ridge=1e-8,
                             linsolve_tol=1e-8)

    def positions(diameter):
        return solver.run(x_star, diameter, mode="jvp")

    (x_rt, info), (dx_rt, _) = jax.jvp(positions, (theta,), (1.0,))
    drift = float(jnp.max(jnp.abs(dx_rt - dx)))
    print(f"runtime polish: converged={bool(info.converged)} in "
          f"{int(info.iterations)} steps")
    print(f"runtime forward-mode sensitivity: L1 norm "
          f"{float(jnp.sum(jnp.abs(dx_rt))):.3f}, "
          f"max |Δ| vs root_jvp = {drift:.2e}")
    assert drift < 1e-4, f"runtime JVP drifted from root_jvp: {drift}"

    # -- batched diameter sweep on an auto-sized mesh --------------------
    # B tangent systems (∂F/∂x)|_{θ_b} dx_b = -∂F/∂θ_b, one per diameter.
    # The mesh extent is NOT hardcoded: auto_mesh_size consults the
    # autotune cost model (measured TuningCache entries when present, the
    # roofline fallback otherwise), so on one device this runs the
    # single-device path and on a pod it picks the measured-best extent.
    Bn = 8
    thetas = theta + 0.005 * jnp.arange(Bn)
    flat = x_star.reshape(-1)
    d_sys = flat.shape[0]

    def F_flat(xf, diameter):
        return -jax.grad(lambda x: pair_energy(x, diameter))(
            xf.reshape(x_star.shape)).reshape(-1)

    H = jax.vmap(lambda th: -jax.jacfwd(F_flat)(flat, th))(thetas)
    rhs = jax.vmap(lambda th: jax.jacfwd(
        lambda t: F_flat(flat, t))(th))(thetas)

    n_mesh = auto_mesh_size(Bn, d_sys)
    mesh = make_solve_mesh(devices=n_mesh)
    batched = ShardedOperator(
        operators.DenseOperator(H, symmetric=True), mesh, P("data", None))
    dx_sweep = linear_solve.solve(batched, rhs, method="auto", tol=1e-8)
    drift_b = float(jnp.max(jnp.abs(
        dx_sweep[0].reshape(x_star.shape) - dx)))
    print(f"batched diameter sweep: B={Bn} systems of dim {d_sys} on a "
          f"{n_mesh}-device mesh (auto-sized), "
          f"max |Δ| vs root_jvp at θ_0 = {drift_b:.2e}")
    assert drift_b < 1e-6, f"batched sweep drifted at base θ: {drift_b}"
    print("OK")


if __name__ == "__main__":
    main()
