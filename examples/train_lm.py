"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps with the full production stack (sharding-ready step, AdamW + cosine,
checkpointing, deterministic restart-safe data, straggler monitor).

On this CPU container the default is a scaled-down width so the run
completes in minutes; pass --full-100m on real hardware.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-100m]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMStream
from repro.optim import adamw, schedules
from repro.runtime import (StragglerMonitor, TrainStepConfig,
                           make_train_state, make_train_step,
                           run_train_loop)


def make_cfg(full: bool) -> ArchConfig:
    if full:   # ~100M params
        return ArchConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32000)
    return ArchConfig(name="lm-tiny", family="dense", num_layers=4,
                      d_model=256, num_heads=4, num_kv_heads=2,
                      d_ff=768, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.full_100m)
    optimizer = adamw(schedules.linear_warmup_cosine(
        3e-3, warmup=20, total=args.steps), weight_decay=0.01)
    step_fn = jax.jit(make_train_step(
        cfg, optimizer, TrainStepConfig(microbatches=2, remat=False)))
    state = make_train_state(cfg, optimizer, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(state.params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    stream = SyntheticLMStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def data_iter():
        s = 0
        while True:
            yield s, stream.batch_at(s)
            s += 1

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()
    t0 = time.perf_counter()
    state, hist = run_train_loop(step_fn, state, data_iter(),
                                 num_steps=args.steps,
                                 checkpoint_manager=mgr,
                                 checkpoint_every=100, monitor=mon,
                                 log_every=20)
    dt = time.perf_counter() - t0
    for h in hist:
        print(f"  step {int(h['step']):4d}  loss {h['loss']:.4f}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
