"""Deep-equilibrium (DEQ) transformer block with implicit-diff backward.

The block's forward pass solves z* = cell(z*, x; w) with Anderson
acceleration; the backward pass uses the paper's machinery
(``custom_fixed_point``) so memory is O(1) in solver depth.  We verify the
gradient against full unrolled backprop and show the memory argument.

Run: PYTHONPATH=src python examples/deq_block.py
"""
import jax
import jax.numpy as jnp

from repro.core import deq_fixed_point

jax.config.update("jax_enable_x64", True)


def main():
    key = jax.random.PRNGKey(0)
    d, d_ff = 32, 64
    k1, k2, k3 = jax.random.split(key, 3)
    w = {
        "w1": 0.9 / jnp.sqrt(d) * jax.random.normal(k1, (d, d_ff)),
        "w2": 0.9 / jnp.sqrt(d_ff) * jax.random.normal(k2, (d_ff, d)),
    }
    x = jax.random.normal(k3, (d,))

    def cell(z, x, w):
        """A weight-tied residual MLP block: z ← norm(x + MLP(z))."""
        h = jnp.tanh(z @ w["w1"]) @ w["w2"]
        out = x + 0.5 * h
        return out / (1.0 + 0.1 * jnp.linalg.norm(out))

    # the forward solve is a runtime AndersonAcceleration.run(): one masked
    # while_loop with OptInfo diagnostics, implicit-diff'd automatically
    z_star, info = deq_fixed_point(cell, jnp.zeros(d), x, w, fwd_iters=100,
                                   fwd_tol=1e-12, bwd_solve="normal_cg",
                                   bwd_iters=200, return_info=True)
    print(f"forward solve: converged={bool(info.converged)} in "
          f"{int(info.iterations)} iters (residual {float(info.error):.1e})")

    def loss_deq(w):
        z = deq_fixed_point(cell, jnp.zeros(d), x, w, fwd_iters=100,
                            fwd_tol=1e-12, bwd_solve="normal_cg",
                            bwd_iters=200)
        return jnp.sum(z ** 2)

    def loss_unrolled(w, depth=100):
        z = jnp.zeros(d)
        for _ in range(depth):
            z = cell(z, x, w)
        return jnp.sum(z ** 2)

    g_deq = jax.grad(loss_deq)(w)
    g_unr = jax.grad(loss_unrolled)(w)
    err = max(float(jnp.max(jnp.abs(g_deq[k] - g_unr[k]))) for k in w)
    print(f"grad err (implicit vs 100-layer unrolled): {err:.2e}")
    assert err < 1e-4

    # the memory argument: unrolled backprop stores O(depth) activations;
    # the DEQ backward stores ONE residual point + CG workspace.
    depth = 100
    act_bytes_unrolled = depth * (d + d_ff) * 8
    act_bytes_deq = (d + d_ff) * 8 * 3
    print(f"activation memory: unrolled ≈ {act_bytes_unrolled/1e3:.1f}KB, "
          f"implicit ≈ {act_bytes_deq/1e3:.1f}KB "
          f"({act_bytes_unrolled/act_bytes_deq:.0f}x)")
    print("OK")


if __name__ == "__main__":
    main()
