"""Bilevel data reweighting of LM training — the paper's technique as a
first-class framework feature.

Outer: learn per-domain mixture weights θ (simplex) over two synthetic data
domains, one clean and one corrupted, to minimize validation loss.
Inner: ridge-regularized logistic LM-head fit on the θ-weighted data,
solved by the state-based runtime's ``LBFGS`` — the solver declares its own
stationarity condition, so the hypergradient flows through the inner optimum
automatically (no unrolling, one CG solve per outer step) and the driver
surfaces the inner solve's ``OptInfo`` diagnostics.

Expected outcome: the learned weights downweight the corrupted domain.

Run: PYTHONPATH=src python examples/bilevel_datareweight.py
"""
import jax
import jax.numpy as jnp

from repro.core import LBFGS, bilevel

jax.config.update("jax_enable_x64", True)


def main():
    key = jax.random.PRNGKey(0)
    p, k = 32, 8                      # feature dim, classes
    n_per = 128
    kw, k1, k2, k3 = jax.random.split(key, 4)
    w_true = jax.random.normal(kw, (p, k))

    def make_domain(kk, corrupt):
        X = jax.random.normal(kk, (n_per, p))
        logits = X @ w_true
        y = jnp.argmax(logits, -1)
        if corrupt:   # random labels: harmful domain
            y = jax.random.randint(jax.random.fold_in(kk, 9), (n_per,),
                                   0, k)
        return X, y

    Xa, ya = make_domain(k1, corrupt=False)
    Xb, yb = make_domain(k2, corrupt=True)
    Xval, yval = make_domain(k3, corrupt=False)

    def xent(w, X, y):
        return -jnp.mean(jax.nn.log_softmax(X @ w)[jnp.arange(len(y)), y])

    def inner_obj(w, lam):
        # λ ∈ R²: softmax-normalized domain weights
        mix = jax.nn.softmax(lam)
        return (mix[0] * xent(w, Xa, ya) + mix[1] * xent(w, Xb, yb)
                + 5e-3 * jnp.sum(w ** 2))

    # the runtime solver declares its optimality mapping (stationarity of
    # inner_obj); solve_bilevel routes its backward solve through "cg".
    # tol is set where this problem's L-BFGS actually lands within the
    # iteration budget, so OptInfo reports an honest converged=True
    inner_solver = LBFGS(inner_obj, maxiter=200, stepsize=0.5, tol=1e-5)

    def outer_loss(w, lam):
        return xent(w, Xval, yval)

    sol = bilevel.solve_bilevel(
        outer_loss, inner_solver, jnp.zeros(2), jnp.zeros((p, k)),
        outer_steps=30, outer_lr=0.5, momentum=0.9, solve="cg")

    mix = jax.nn.softmax(sol.theta)
    print(f"val loss: {sol.outer_values[0]:.4f} -> "
          f"{sol.outer_values[-1]:.4f}")
    print(f"last inner solve: converged={bool(sol.inner_info.converged)} "
          f"in {int(sol.inner_info.iterations)} iters "
          f"(error {float(sol.inner_info.error):.1e})")
    print(f"learned domain weights: clean={mix[0]:.3f} "
          f"corrupted={mix[1]:.3f}")
    assert mix[0] > 0.7, "expected the clean domain to dominate"
    assert sol.outer_values[-1] < sol.outer_values[0]
    print("OK — corrupted domain downweighted via implicit hypergradients")


if __name__ == "__main__":
    main()
