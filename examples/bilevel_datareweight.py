"""Bilevel data reweighting of LM training — the paper's technique as a
first-class framework feature.

Outer: learn per-domain mixture weights θ (simplex) over two synthetic data
domains, one clean and one corrupted, to minimize validation loss.
Inner: ridge-regularized logistic LM-head fit on the θ-weighted data.

Two modes:

* default — a small in-memory problem solved by the state-based runtime's
  ``LBFGS``: the solver declares its own stationarity condition, so the
  hypergradient flows through the inner optimum automatically (no
  unrolling, one CG solve per outer step) and the driver surfaces the
  inner solve's ``OptInfo`` diagnostics.
* ``--data-scale`` — the same reweighting problem at data scale: the
  training set is 64 minibatches' worth of ``SyntheticLMStream`` tokens
  (collected through a seekable ``PrefetchIterator``), the inner solver is
  a stochastic ``Adam`` over a deterministic ``MinibatchSampler``, and the
  hypergradient is taken at the Polyak-averaged iterate through a
  ``SampledJacobianOperator`` — full-batch anything never materializes in
  the inner loop.  The final inner fit also replays through the production
  ``train_loop`` via ``make_stochastic_train_step`` to show the host-side
  wiring.

Expected outcome (both modes): the learned weights downweight the
corrupted domain and validation loss decreases.

Run: PYTHONPATH=src python examples/bilevel_datareweight.py [--data-scale]
"""
import sys

import jax
import jax.numpy as jnp

from repro.core import LBFGS, bilevel

jax.config.update("jax_enable_x64", True)


def main():
    key = jax.random.PRNGKey(0)
    p, k = 32, 8                      # feature dim, classes
    n_per = 128
    kw, k1, k2, k3 = jax.random.split(key, 4)
    w_true = jax.random.normal(kw, (p, k))

    def make_domain(kk, corrupt):
        X = jax.random.normal(kk, (n_per, p))
        logits = X @ w_true
        y = jnp.argmax(logits, -1)
        if corrupt:   # random labels: harmful domain
            y = jax.random.randint(jax.random.fold_in(kk, 9), (n_per,),
                                   0, k)
        return X, y

    Xa, ya = make_domain(k1, corrupt=False)
    Xb, yb = make_domain(k2, corrupt=True)
    Xval, yval = make_domain(k3, corrupt=False)

    def xent(w, X, y):
        return -jnp.mean(jax.nn.log_softmax(X @ w)[jnp.arange(len(y)), y])

    def inner_obj(w, lam):
        # λ ∈ R²: softmax-normalized domain weights
        mix = jax.nn.softmax(lam)
        return (mix[0] * xent(w, Xa, ya) + mix[1] * xent(w, Xb, yb)
                + 5e-3 * jnp.sum(w ** 2))

    # the runtime solver declares its optimality mapping (stationarity of
    # inner_obj); solve_bilevel routes its backward solve through "cg".
    # tol is set where this problem's L-BFGS actually lands within the
    # iteration budget, so OptInfo reports an honest converged=True
    inner_solver = LBFGS(inner_obj, maxiter=200, stepsize=0.5, tol=1e-5)

    def outer_loss(w, lam):
        return xent(w, Xval, yval)

    sol = bilevel.solve_bilevel(
        outer_loss, inner_solver, jnp.zeros(2), jnp.zeros((p, k)),
        outer_steps=30, outer_lr=0.5, momentum=0.9, solve="cg")

    mix = jax.nn.softmax(sol.theta)
    print(f"val loss: {sol.outer_values[0]:.4f} -> "
          f"{sol.outer_values[-1]:.4f}")
    print(f"last inner solve: converged={bool(sol.inner_info.converged)} "
          f"in {int(sol.inner_info.iterations)} iters "
          f"(error {float(sol.inner_info.error):.1e})")
    print(f"learned domain weights: clean={mix[0]:.3f} "
          f"corrupted={mix[1]:.3f}")
    assert mix[0] > 0.7, "expected the clean domain to dominate"
    assert sol.outer_values[-1] < sol.outer_values[0]
    print("OK — corrupted domain downweighted via implicit hypergradients")


def main_data_scale():
    """Data-scale mode: stochastic inner solver over a streamed dataset."""
    import numpy as np

    from repro.data.pipeline import (DataConfig, PrefetchIterator,
                                     SyntheticLMStream)
    from repro.runtime.train_loop import train_loop
    from repro.stochastic import (Adam, MinibatchSampler,
                                  make_stochastic_train_step,
                                  stochastic_data_iter)

    vocab, seq_len = 32, 8
    stream_batch = 32                 # examples per stream step
    minibatch = 16                    # inner-solver minibatch B
    steps_per_domain = 16             # 16 * 32 = 512 examples per domain

    # -- build the dataset from the production stream, via the seekable
    #    prefetch iterator (closed cleanly when the block exits) ----------
    def collect(seed, corrupt):
        cfg = DataConfig(vocab_size=vocab, seq_len=seq_len,
                         global_batch=stream_batch, seed=seed)
        with PrefetchIterator(SyntheticLMStream(cfg), daemon=False) as it:
            xs, ys = zip(*(it.batch_at(s) for s in range(steps_per_domain)))
        x = np.concatenate(xs, axis=0)
        y = np.concatenate(ys, axis=0)
        if corrupt:   # destroy the bigram structure: random labels
            rng = np.random.default_rng(seed + 999)
            y = rng.integers(0, vocab, size=y.shape).astype(np.int32)
        return x, y

    x_clean, y_clean = collect(seed=0, corrupt=False)
    x_bad, y_bad = collect(seed=1, corrupt=True)
    x = np.concatenate([x_clean, x_bad], axis=0)
    y = np.concatenate([y_clean, y_bad], axis=0)
    dom = np.concatenate([np.zeros(len(x_clean), np.int32),
                          np.ones(len(x_bad), np.int32)])
    n = len(x)
    assert n >= 64 * minibatch, (n, minibatch)   # dataset ≥ 64× minibatch

    # held-out clean validation split (disjoint stream steps)
    val_cfg = DataConfig(vocab_size=vocab, seq_len=seq_len,
                         global_batch=stream_batch, seed=0)
    val_stream = SyntheticLMStream(val_cfg)
    xv, yv = zip(*(val_stream.batch_at(steps_per_domain + s)
                   for s in range(4)))
    xv, yv = np.concatenate(xv, axis=0), np.concatenate(yv, axis=0)

    # -- the train_lm-style loss: bigram LM head W[token] -> next-token
    #    logits, per-example CE, θ-weighted by domain -----------------------
    def example_ce(W, xb, yb):
        logits = W[xb]                               # (B, L, vocab)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, yb[..., None], axis=-1)[..., 0]
        return jnp.mean(ce, axis=-1)                 # (B,) per-example CE

    def weighted_ce(W, batch, lam):
        xb, (yb, db) = batch
        mix = jax.nn.softmax(lam)
        # ×2 so the weighted mean matches the full two-domain objective
        weights = 2.0 * mix[db]
        return jnp.mean(weights * example_ce(W, xb, yb))

    def inner_fun(W, batch, lam):
        return weighted_ce(W, batch, lam) + 1e-2 * jnp.sum(W ** 2)

    def outer_loss(W, lam):
        return jnp.mean(example_ce(W, jnp.asarray(xv), jnp.asarray(yv)))

    # batch pytree (x, (y, dom)) so the train_loop's (x, y) unpacking works
    sampler = MinibatchSampler(
        data=(jnp.asarray(x), (jnp.asarray(y), jnp.asarray(dom))),
        batch_size=minibatch, seed=0)
    inner_solver = Adam(
        inner_fun, sampler=sampler, stepsize=5e-2, epochs=2,
        averaging="polyak", average_from=sampler.num_batches,
        # hypergrad at the averaged iterate through a SampledJacobianOperator
        # (4 resampled minibatches); CG on the sampled system — unpreconditioned
        # since jacobi diagonal probing is O(d) matvecs at vocab² params
        backward="exact", solve="cg", precond=None, backward_batches=4,
        linsolve_tol=1e-4, linsolve_maxiter=100)

    W0 = jnp.zeros((vocab, vocab))
    sol = bilevel.solve_bilevel(
        outer_loss, inner_solver, jnp.zeros(2), W0,
        outer_steps=6, outer_lr=2.0, momentum=0.5)

    mix = jax.nn.softmax(sol.theta)
    print(f"dataset: n={n} examples ({n // minibatch} minibatches of "
          f"{minibatch}; {64}x floor satisfied)")
    print(f"val loss: {sol.outer_values[0]:.4f} -> "
          f"{sol.outer_values[-1]:.4f}")
    print(f"last inner solve: full-batch residual "
          f"{float(sol.inner_info.error):.3e}, hypergrad error estimate "
          f"{float(sol.inner_info.hypergrad_error_estimate):.3f}")
    print(f"learned domain weights: clean={mix[0]:.3f} "
          f"corrupted={mix[1]:.3f}")
    assert mix[0] > 0.5, "expected the clean domain to dominate"
    assert sol.outer_values[-1] < sol.outer_values[0], "val loss must drop"

    # -- replay the final inner fit through the production train_loop -------
    step_fn = make_stochastic_train_step(inner_solver, sol.theta)

    def train_step(carry, xb, yb):
        return step_fn(carry, xb, yb)

    carry0 = (W0, inner_solver.init_state(W0, sol.theta))
    carry, history = train_loop(
        train_step, carry0, stochastic_data_iter(sampler),
        num_steps=inner_solver.num_steps(), log_every=16)
    # minibatch losses are noisy and the ridge term grows off W0=0; judge
    # the replay on the full-batch weighted data-fit term
    fit_before = float(weighted_ce(W0, sampler.data, sol.theta))
    fit_after = float(weighted_ce(carry[0], sampler.data, sol.theta))
    print(f"train_loop replay: {len(history)} logged steps, "
          f"full weighted CE {fit_before:.4f} -> {fit_after:.4f}")
    assert fit_after < fit_before
    print("OK — corrupted domain downweighted with a stochastic inner "
          "solver at data scale")


if __name__ == "__main__":
    if "--data-scale" in sys.argv[1:]:
        main_data_scale()
    else:
        main()
